"""CAP tests: paper §4.2 / Fig 11 behaviours + allocator properties."""

import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core.cap import CapAllocator
from repro.core.color import VCOL
from repro.core.host_model import CotenantWorkload, poisoner_gen
from tests.conftest import make_vm, N_COLORS


def _lists(n_colors=4, per=8):
    return {c: [c * 100 + i for i in range(per)] for c in range(n_colors)}


def test_single_color_until_exhausted_then_rollover():
    cap = CapAllocator(_lists(), use_contention=False)
    first = [cap.allocate() for _ in range(8)]
    colors = {cap.page_color[p] for p in first}
    assert len(colors) == 1                     # SRM-buffer behaviour
    nxt = cap.allocate()
    assert cap.page_color[nxt] not in colors    # rolled to the next color
    assert cap.stats.color_rollovers == 1


def test_hottest_color_first():
    cap = CapAllocator(_lists())
    cap.update_contention({0: 0.1, 1: 5.0, 2: 0.2, 3: 0.3})
    cap.update_contention({0: 0.1, 1: 5.0, 2: 0.2, 3: 0.3})
    cap.update_contention({0: 0.1, 1: 5.0, 2: 0.2, 3: 0.3})
    p = cap.allocate()
    assert cap.page_color[p] == 1               # poisoned zone absorbs traffic


def test_recolor_requires_three_intervals():
    cap = CapAllocator(_lists())
    hot0 = {0: 9.0, 1: 0.1, 2: 0.1, 3: 0.1}
    hot2 = {0: 0.1, 1: 0.1, 2: 9.0, 3: 0.1}
    for _ in range(3):
        cap.step_interval(hot0)
    assert cap.committed_hottest == 0
    for p in range(4):
        cap.allocate()
    assert not cap.step_interval(hot2)          # 1st challenger interval
    assert not cap.step_interval(hot2)          # 2nd
    assert cap.step_interval(hot2)              # 3rd -> recolor + reclaim
    assert cap.committed_hottest == 2
    assert cap.allocated_pages == []            # page cache dropped
    assert cap.stats.recolor_events == 1
    assert cap.page_color[cap.allocate()] == 2


def test_pressure_reclaim_is_not_a_recolor_event():
    """Regression: `reclaim_all()` is also the memory-pressure path, so it
    must not count as an adaptive recoloring — only `step_interval`'s
    3-interval commit does (both bump the reason-agnostic `reclaims`)."""
    cap = CapAllocator(_lists())
    for _ in range(5):
        cap.allocate()
    dropped = cap.reclaim_all()                 # memory pressure
    assert len(dropped) == 5
    assert cap.stats.recolor_events == 0
    assert cap.stats.reclaims == 1
    hot0 = {0: 9.0, 1: 0.1, 2: 0.1, 3: 0.1}
    hot2 = {0: 0.1, 1: 0.1, 2: 9.0, 3: 0.1}
    for _ in range(3):
        cap.step_interval(hot0)                 # confirms the initial hottest
    for _ in range(3):
        cap.step_interval(hot2)                 # genuine recolor on the 3rd
    assert cap.stats.recolor_events == 1
    assert cap.stats.reclaims == 2


def test_unmeasured_colors_allocatable_last():
    """Colors with no contention measurement (e.g. monitored sets pruned on
    few-row geometries) still allocate — after every ranked color."""
    cap = CapAllocator(_lists())
    for _ in range(3):
        cap.step_interval({2: 9.0, 3: 0.1})     # colors 0/1 never measured
    pages = [cap.allocate() for _ in range(32)]
    assert all(p is not None for p in pages)
    assert cap.page_color[pages[0]] == 2        # measured-hottest first
    assert {cap.page_color[p] for p in pages} == {0, 1, 2, 3}


def test_exhaustion_falls_back():
    cap = CapAllocator({0: [1], 1: []}, use_contention=False)
    assert cap.allocate() == 1
    assert cap.allocate() is None
    assert cap.stats.fallback_allocs == 1


@settings(max_examples=40, deadline=None)
@given(per=st.integers(1, 6), n_alloc=st.integers(0, 40),
       intervals=st.integers(0, 6), seed=st.integers(0, 9))
def test_property_page_conservation(per, n_alloc, intervals, seed):
    """Pages are never duplicated or lost across alloc/recolor cycles."""
    rng = np.random.default_rng(seed)
    lists = _lists(per=per)
    universe = sorted(p for lst in lists.values() for p in lst)
    cap = CapAllocator(lists)
    for i in range(intervals):
        rates = {c: float(rng.random() * 10) for c in range(4)}
        cap.step_interval(rates)
        for _ in range(n_alloc // max(1, intervals)):
            cap.allocate()
    held = list(cap.allocated_pages)
    free = [p for lst in cap.free_lists.values() for p in lst]
    assert sorted(held + free) == universe


def test_cap_reduces_pollution_end_to_end():
    """Fig 11 (qualitative): a streaming scan through the page cache evicts
    a high-locality working set under vanilla allocation; CAP confines the
    damage to one LLC zone; CAP+vscan steers it into the poisoned zone.

    Measured as the mean access latency of the workload's working set.
    """
    host, vm = make_vm(mapping="fragmented", seed=31, n_guest_pages=1 << 13)
    vcol = VCOL(vm)
    cf = vcol.build_color_filters(n_colors=N_COLORS, ways=8, seed=33)

    # high-locality working set: 16 pages of virtual color 1, all at offset 0
    pages = vm.alloc_pages(560)
    colors = vcol.identify_colors_parallel(cf, pages)
    work_pages = [int(p) for p, c in zip(pages, colors) if c == 1][:16]
    work_lines = np.array([vm.gva(p, 0) for p in work_pages])
    stream_pool = {c: [int(p) for p, cc in zip(pages, colors)
                       if cc == c and int(p) not in work_pages]
                   for c in range(N_COLORS)}
    n_stream = 120

    def run(policy: str) -> float:
        if policy == "vanilla":
            rng = np.random.default_rng(5)
            mixed = [p for c in range(N_COLORS)
                     for p in stream_pool[c][:n_stream // N_COLORS]]
            order = list(rng.permutation(mixed))
            alloc_colors = None
        elif policy == "cap":
            cap = CapAllocator({c: list(v) for c, v in stream_pool.items()},
                               use_contention=False)
            order = [cap.allocate() for _ in range(n_stream)]
            alloc_colors = {cap.page_color[p] for p in order}
        else:  # cap+vscan: poisoner makes color 0 hottest
            cap = CapAllocator({c: list(v) for c, v in stream_pool.items()})
            for _ in range(3):
                cap.step_interval({0: 9.0, 1: 0.1, 2: 0.1, 3: 0.1})
            order = [cap.allocate() for _ in range(n_stream)]
            alloc_colors = {cap.page_color[p] for p in order}
            # structural claim (§4.2): traffic steered into the hottest zone
            assert alloc_colors == {0}
        lat = []
        for _ in range(4):
            vm.access(work_lines)
            # streaming page-cache scan (fio): same offset as the working set
            stream_lines = np.array([vm.gva(p, 0) for p in order])
            vm.access(stream_lines)
            vm.warm_timer()
            lat.append(float(vm.timed_access(work_lines).mean()))
        return float(np.mean(lat[1:]))

    lat_vanilla = run("vanilla")
    lat_cap = run("cap")
    lat_cap_vscan = run("cap+vscan")
    # CAP confines pollution to one zone away from the working set; steering
    # into the poisoned zone must not hurt the workload either.
    assert lat_cap < lat_vanilla
    assert lat_cap_vscan <= lat_cap * 1.05
