"""Scenario-matrix + batched probe engine tests.

Covers the two tentpole pieces end to end:
  * simulator equivalence — the batched multi-set Prime+Probe engine
    (`cachesim.access_streams_batched`) vs the seed per-access `lax.scan`
    path, exactly, under both `lru` and `random` replacement;
  * the `CachePlatform` registry — VEV/VCOL success criteria parametrized
    across every registered platform (including the CAT-partitioned one,
    whose *effective* associativity shrinks to the allocation), plus the
    `run_cachex` end-to-end driver.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cachesim
from repro.core.cachesim import CacheGeometry, MachineGeometry
from repro.core.color import VCOL, color_accuracy
from repro.core.eviction import VEV
from repro.core.platforms import (CachePlatform, all_platforms, get_platform,
                                  list_platforms)
from repro.core.runner import run_cachex

PLATFORM_NAMES = list_platforms()


# ---------------------------------------------------------------------------
# batched probe engine vs seed scan path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("replacement", ["lru", "random"])
def test_batched_engine_matches_sequential_scan(replacement):
    """Every lane of one batched dispatch must be bit-identical to running
    that lane's stream alone through the seed `access_stream` path from the
    same machine snapshot (with the lane's forked rng under `random`)."""
    geom = MachineGeometry(n_domains=1, cores_per_domain=2,
                           l2=CacheGeometry(64, 4),
                           llc=CacheGeometry(128, 4, 2),
                           replacement=replacement)
    state = cachesim.init_machine(geom)
    rng = np.random.default_rng(11)
    warm = rng.integers(0, 1024, 400).astype(np.int32)
    state, _ = cachesim.access_stream(state, geom, jnp.asarray(warm),
                                      jnp.zeros(400, jnp.int32),
                                      jnp.zeros(400, bool))
    B, T = 6, 48
    blocks = rng.integers(0, 1024, (B, T)).astype(np.int32)
    blocks[blocks % 7 == 0] = -1          # padding holes mid-stream
    cores = rng.integers(0, geom.n_cores, B).astype(np.int32)
    lats_b = np.asarray(cachesim.access_streams_batched(
        state, geom, jnp.asarray(blocks), jnp.asarray(cores),
        jnp.zeros(B, bool)))
    for i in range(B):
        st = jax.tree_util.tree_map(jnp.copy, state)
        st["rng"] = (state["rng"] +
                     jnp.uint32(i) * jnp.uint32(cachesim.RNG_LANE_STRIDE))
        _, lats_s = cachesim.access_stream(
            st, geom, jnp.asarray(blocks[i]),
            jnp.full(T, cores[i], jnp.int32), jnp.zeros(T, bool))
        np.testing.assert_array_equal(lats_b[i], np.asarray(lats_s),
                                      err_msg=f"lane {i} ({replacement})")


@pytest.mark.parametrize("replacement", ["lru", "random"])
def test_batched_evicts_agrees_with_sequential_evicts(replacement):
    """VEV's batched group test and the seed per-test path must reach the
    same verdicts on identical (target, candidates) eviction tests (for
    `random`, both run enough votes for the majority to be stable)."""
    from tests.conftest import make_vm
    host, vm = make_vm(replacement=replacement, seed=31)
    votes, reps = (5, 4) if replacement == "random" else (1, 1)
    vev_seq = VEV(vm, votes=votes, prime_reps=reps, use_batch=False)
    vev_bat = VEV(vm, votes=votes, prime_reps=reps, use_batch=True)
    pages = vm.alloc_pages(512)
    target = vm.gva(int(pages[0]), 0)
    key = vm.hypercall_llc_setslice(target)
    cong = [vm.gva(int(p), 0) for p in pages[1:]
            if vm.hypercall_llc_setslice(vm.gva(int(p), 0)) == key]
    other = [vm.gva(int(p), 0) for p in pages[1:]
             if vm.hypercall_llc_setslice(vm.gva(int(p), 0)) != key]
    ways = host.geom.llc.n_ways
    tests = [
        (target, np.array(cong[:ways + 2])),          # clearly evicts
        (target, np.array(cong[:ways - 2] + other[:8])),  # too few congruent
        (target, np.array(other[:2 * ways])),         # disjoint sets
    ]
    seq = np.array([vev_seq.evicts(t, c, "llc") for t, c in tests])
    bat = vev_bat.evicts_many(tests, "llc")
    np.testing.assert_array_equal(seq, bat)
    assert list(seq) == [True, False, False]


# ---------------------------------------------------------------------------
# platform registry
# ---------------------------------------------------------------------------

def test_registry_exposes_scenario_matrix():
    assert len(PLATFORM_NAMES) >= 4
    assert "skylake_sp" in PLATFORM_NAMES
    kinds = {p.provisioning for p in all_platforms()}
    assert {"dedicated", "cat", "slice", "shared"} <= kinds
    cat = get_platform("skylake_cat")
    assert cat.effective_ways < cat.llc_ways_total
    slicep = get_platform("skylake_slicepart")
    assert slicep.llc.n_slices < slicep.llc_slices_total
    assert any(p.noise for p in all_platforms())


@pytest.mark.parametrize("name", PLATFORM_NAMES)
def test_vev_builds_verified_sets_on_every_platform(name):
    """VEV success criteria across the whole provisioning matrix: minimal
    sets of exactly the *effective* associativity, all lines congruent in
    one (set, slice) — checked via the validation hypercall (§6.2)."""
    plat = get_platform(name)
    host, vm = plat.make_host_vm(seed=5)
    vev = VEV(vm, votes=plat.votes, prime_reps=plat.prime_reps)
    ways = plat.effective_ways
    pool = vev.make_pool(0, ways=ways,
                         n_uncontrollable_rows=plat.n_llc_rows_per_offset,
                         n_slices=plat.llc.n_slices)
    sets = vev.build_for_offset(0, pool, ways=ways, level="llc", max_sets=2,
                                seed=6)
    assert len(sets) == 2, f"{name}: built {len(sets)}/2"
    for es in sets:
        assert len(es) == ways, f"{name}: |set|={len(es)} != ways={ways}"
        keys = {vm.hypercall_llc_setslice(int(g)) for g in es.gvas}
        assert len(keys) == 1, f"{name}: set straddles {keys}"


@pytest.mark.parametrize("name", PLATFORM_NAMES)
def test_vcol_virtual_colors_on_every_platform(name):
    """VCOL color filters + parallel filtering across the matrix; quiet
    scenarios must reach the paper's 100% accuracy, noisy ones >= 90%."""
    plat = get_platform(name)
    host, vm = plat.make_host_vm(seed=7)
    vcol = VCOL(vm, vev=VEV(vm, votes=plat.votes,
                            prime_reps=plat.prime_reps))
    cf = vcol.build_color_filters(n_colors=plat.n_l2_colors,
                                  ways=plat.l2.n_ways, seed=8)
    assert cf.n_colors == plat.n_l2_colors, name
    pages = vm.alloc_pages(12 * plat.n_l2_colors)
    colors = vcol.identify_colors_parallel(cf, pages)
    acc = color_accuracy(vm, pages, colors, plat.n_l2_colors)
    if not plat.l2_filter_reliable:
        # small CAT allocations: the simulator's combined LLC/directory
        # entry back-invalidates L2 lines mid-filter (real CAT leaves the
        # snoop-filter directory unpartitioned) — colors stay informative
        # but lose the 100% guarantee
        assert acc >= 0.5, f"{name}: accuracy {acc}"
    elif plat.noise:
        assert acc >= 0.9, f"{name}: accuracy {acc}"
    else:
        assert acc == 1.0, f"{name}: accuracy {acc}"


def test_cat_partitioning_shrinks_detected_associativity():
    """Paper Table 3: under CAT way-partitioning the VM *discovers* its
    allocation — detected ways == allocated ways < hardware ways."""
    cat = get_platform("skylake_cat")
    host, vm = cat.make_host_vm(seed=9)
    vev = VEV(vm)
    pool = vev.make_pool(0, ways=cat.llc_ways_total,
                         n_uncontrollable_rows=cat.n_llc_rows_per_offset,
                         n_slices=cat.llc.n_slices)
    detected = vev.probe_associativity(pool, "llc", seed=10)
    assert detected == cat.effective_ways
    assert detected < cat.llc_ways_total


# ---------------------------------------------------------------------------
# end-to-end driver
# ---------------------------------------------------------------------------

def test_run_cachex_dedicated_baseline():
    r = run_cachex("skylake_sp", seed=1, monitor_intervals=2)
    assert r.vev_success_rate == 1.0
    assert r.detected_ways == 8
    assert r.vcol_accuracy == 1.0
    assert r.vscan_sets > 0
    assert r.vscan_contended_rate > r.vscan_idle_rate
    assert r.cap_allocated > 0
    assert r.dispatches > 0 and r.accesses > 0
    assert r.csv_row().startswith("skylake_sp,dedicated,")
    assert r.csv_header().startswith("platform,provisioning,")


def test_run_cachex_cat_scenario():
    r = run_cachex("skylake_cat", seed=2, monitor_intervals=2)
    assert r.vev_success_rate == 1.0
    assert r.detected_ways == 4          # the CAT allocation, not 8
    assert r.provisioning == "cat"
