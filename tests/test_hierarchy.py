"""Multi-level hierarchy tests (PR 8, paper §3/§6.2).

Covers the four layers the hierarchy subsystem touches: the
:class:`HierarchySpec` inclusion model and its derived predicates
(satellite: ``CachePlatform.l2_filter_reliable`` is now *derived*, the
hand-set values become assertions), the simulator's gated
back-invalidation semantics, per-level attribution scored against the
``hypercall_resident_level`` oracle (full 6-platform x 2-variant sweep;
slow-marked except skylake_sp), and the CAP L2-harvest tier
(grant-hysteresis / revoke-band policy, plus the closed fleet loop
end to end with the co-tenant going quiet -> loud).
"""

import dataclasses
import types

import numpy as np
import pytest

from repro.core import eviction as eviction_mod
from repro.core import hierarchy
from repro.core.cachesim import (LAT_DRAM, LAT_L2, LAT_LLC, MachineGeometry)
from repro.core.cap import L2HarvestTier
from repro.core.eviction import VEV, EvictionSet
from repro.core.fleet import harvest_summary, run_fleet
from repro.core.hierarchy import (HierarchySpec, attribute_levels,
                                  attribution_accuracy, directory_aliasing,
                                  harvest_cores, l2_filter_reliable,
                                  quiet_l2_colors)
from repro.core.host_model import GuestVM, SimHost
from repro.core.platforms import get_platform, list_platforms
from tests.conftest import SMALL_L2, SMALL_LLC

ALL_PLATFORMS = sorted(list_platforms())

# the full sweep is expensive on the big-LLC platforms; tier-1 keeps the
# canonical skylake_sp case, `-m slow` runs the rest
PLATFORM_PARAMS = [
    name if name == "skylake_sp" else pytest.param(name,
                                                   marks=pytest.mark.slow)
    for name in ALL_PLATFORMS
]


# ---------------------------------------------------------------------------
# HierarchySpec + derived predicates (satellite: l2_filter_reliable)
# ---------------------------------------------------------------------------

def test_spec_derives_from_platforms():
    for name in ALL_PLATFORMS:
        plat = get_platform(name)
        spec = HierarchySpec.of(plat)
        assert spec.l2 == plat.l2 and spec.llc == plat.llc
        # every paper platform models an inclusive (directory-backed) LLC
        assert spec.inclusion == "inclusive" and spec.back_invalidates


def test_l2_filter_reliable_is_derived_not_hand_set():
    """The hand-set per-platform values became assertions: only
    skylake_cat (guest-effective LLC associativity 4 < L2's 8) loses the
    filter to back-invalidation false positives."""
    expected = {name: name != "skylake_cat" for name in ALL_PLATFORMS}
    for name in ALL_PLATFORMS:
        plat = get_platform(name)
        derived = l2_filter_reliable(plat.inclusion, plat.l2, plat.llc)
        assert plat.l2_filter_reliable == derived == expected[name], name
        # a non-inclusive variant of the same geometry never
        # back-invalidates, so the filter is reliable everywhere
        assert l2_filter_reliable("non_inclusive", plat.l2, plat.llc)


def test_directory_aliasing_only_on_set_poor_inclusive_llc():
    """The milan_ccx effect (LLC 128 sets < L2 256 sets): a single-color
    L2 pool can over-fill one directory row and back-invalidate lines of
    *other* L2 sets.  No other platform, no LLC-level pool, and no
    non-inclusive variant aliases."""
    for name in ALL_PLATFORMS:
        plat = get_platform(name)
        assert directory_aliasing(plat, "l2") == (name == "milan_ccx"), name
        assert not directory_aliasing(plat, "llc")
        noninc = dataclasses.replace(plat, inclusion="non_inclusive")
        assert not directory_aliasing(noninc, "l2")


def test_spec_rejects_unknown_inclusion():
    with pytest.raises(ValueError):
        HierarchySpec("exclusive", SMALL_L2, SMALL_LLC)
    with pytest.raises(ValueError):
        HierarchySpec("inclusive", SMALL_L2, SMALL_LLC).geometry("l1")


# ---------------------------------------------------------------------------
# simulator semantics: back-invalidation is the inclusion variant, measured
# ---------------------------------------------------------------------------

def _sibling_vm(inclusion):
    geom = MachineGeometry(n_domains=1, cores_per_domain=2,
                           l2=SMALL_L2, llc=SMALL_LLC, inclusion=inclusion)
    host = SimHost(geom, n_host_pages=1 << 14, seed=0)
    return host, GuestVM(host, n_guest_pages=1 << 13, mapping="fragmented",
                         vcpu_cores=[0, 1], seed=0)


@pytest.mark.parametrize("inclusion,level_after,lat_after", [
    ("inclusive", 0, LAT_DRAM),      # LLC eviction back-invalidates the L2
    ("non_inclusive", 2, LAT_L2),    # the private L2 copy survives
])
def test_llc_eviction_vs_private_l2_copy(inclusion, level_after, lat_after):
    """A sibling core evicts the target's LLC set (its own L2 is a
    different core's, so the target's L2 copy is untouched *unless* the
    hierarchy back-invalidates).  The surviving residency level is
    exactly `HierarchySpec.back_invalidates`, and `attribute_levels`
    reads the same story off the probe latency."""
    host, vm = _sibling_vm(inclusion)
    assert HierarchySpec.of(host.geom).back_invalidates == \
        (inclusion == "inclusive")
    pages = vm.alloc_pages(1024)
    a = vm.gva(int(pages[0]), 0)
    vm.access([a], vcpu=0)
    assert vm.hypercall_resident_level(a, vcpu=0) == 2
    key = vm.hypercall_llc_setslice(a)
    cong = [vm.gva(int(p), 0) for p in pages[1:]
            if vm.hypercall_llc_setslice(vm.gva(int(p), 0)) == key]
    vm.access(np.asarray(cong[:SMALL_LLC.n_ways]), vcpu=1)  # fill the set
    assert vm.hypercall_resident_level(a, vcpu=0) == level_after
    vm.warm_timer()
    lat = int(vm.timed_access([a], vcpu=0)[0])
    assert lat == lat_after
    assert int(attribute_levels(np.asarray([lat]))[0]) == level_after


# ---------------------------------------------------------------------------
# per-level attribution vs the hypercall oracle (6 platforms x 2 variants)
# ---------------------------------------------------------------------------

def test_attribute_levels_codes():
    codes = attribute_levels(np.asarray([LAT_L2, LAT_LLC, LAT_DRAM]))
    assert codes.tolist() == [2, 3, 0]


@pytest.mark.parametrize("inclusion", ["inclusive", "non_inclusive"])
@pytest.mark.parametrize("name", PLATFORM_PARAMS)
def test_attribution_matches_hypercall_ground_truth(name, inclusion):
    """§6.2 validation: one uncommitted probe lane per line classifies
    its residency level; the classification must match the
    `hypercall_resident_level` oracle on every platform under both
    inclusion variants.  The working set is sized to straddle all three
    levels (L2-hot tail, LLC-resident overflow, untouched DRAM lines)."""
    plat = get_platform(name)
    if plat.inclusion != inclusion:
        plat = dataclasses.replace(plat, inclusion=inclusion)
    host, vm = plat.make_host_vm(seed=7, with_noise=False)
    pages = vm.alloc_pages(96)
    gvas = [vm.gva(int(p), 0) for p in pages]
    vm.access(np.asarray(gvas[:64]))     # mixed L2/LLC; last 32 stay DRAM
    truth = np.asarray([vm.hypercall_resident_level(g) for g in gvas])
    assert len(np.unique(truth)) >= 2    # non-vacuous: levels differ
    acc = attribution_accuracy(vm, gvas)
    assert acc == 1.0, (name, inclusion, acc)


# ---------------------------------------------------------------------------
# harvest helpers + the CAP L2 tier
# ---------------------------------------------------------------------------

def test_quiet_l2_colors_ranked_and_unmeasured_excluded():
    rates = {0: 0.30, 1: 0.00, 2: 0.04}   # color 3 unmeasured -> never
    assert quiet_l2_colors(rates, threshold=0.05) == [1, 2]
    assert quiet_l2_colors({}, threshold=0.05) == []


def test_harvest_cores_excludes_and_ranks():
    rates = {0: 0.0, 1: 0.02, 2: 9.0, 3: 0.0}
    assert harvest_cores(rates, 0.05) == [0, 3, 1]
    assert harvest_cores(rates, 0.05, exclude=(0,)) == [3, 1]


def _tier(**kw):
    kw.setdefault("hysteresis", 3)
    return L2HarvestTier(HierarchySpec.of(get_platform("skylake_sp")), **kw)


def test_tier_grants_only_after_quiet_streak():
    tier = _tier(quiet_threshold=0.05)
    for i in range(2):
        tier.step_interval({0: 0.0})
        assert tier.granted == [], i
    tier.step_interval({0: 0.0})
    assert tier.granted == [0] and tier.stats.core_grants == 1
    # a loud interlude resets the streak
    tier2 = _tier(quiet_threshold=0.05)
    tier2.step_interval({1: 0.0})
    tier2.step_interval({1: 0.0})
    tier2.step_interval({1: 1.0})
    tier2.step_interval({1: 0.0})
    assert tier2.granted == []


def test_tier_revoke_band_tolerates_own_footprint():
    """The grant/revoke band: a granted core whose measured rate rises
    past the quiet threshold but stays under the revoke edge (the tier's
    own promoted-line footprint) keeps the grant; owner-scale pressure
    or losing measurement revokes instantly, no streak."""
    tier = _tier(quiet_threshold=0.05)      # revoke edge = 0.20
    for _ in range(3):
        tier.step_interval({0: 0.0})
    assert tier.granted == [0]
    tier.step_interval({0: 0.15})           # inside the band
    assert tier.granted == [0] and tier.stats.core_revocations == 0
    tier.step_interval({0: 0.5})            # owner woke up
    assert tier.granted == [] and tier.stats.core_revocations == 1
    for _ in range(3):
        tier.step_interval({0: 0.0})
    assert tier.granted == [0]
    tier.step_interval({})                  # unmeasured -> no harvest
    assert tier.granted == []


def test_tier_promotes_hottest_pages_per_color_budget():
    tier = _tier(quiet_threshold=0.05, color_ways=1)
    n_colors = tier.spec.n_l2_colors
    for p in range(3 * n_colors):
        tier.touch(p, n=3 * n_colors - p)   # heat strictly decreasing
    for _ in range(3):
        assignments = tier.step_interval({0: 0.0})
    assert tier.capacity() == n_colors      # 1 core x n_colors x 1 way
    promoted = assignments[0]
    assert len(promoted) == n_colors
    # budget is per L2 color: exactly one page of each color
    assert sorted(p % n_colors for p in promoted) == list(range(n_colors))
    # and within each color, the hottest (lowest-numbered) page won
    assert set(promoted) == set(range(n_colors))
    tier.forget(promoted[:1])
    assert promoted[0] not in tier.promoted
    assert tier.stats.demotions == 1


def test_tier_on_contention_consumes_published_view():
    tier = _tier(quiet_threshold=0.05, hysteresis=1, color_ways=1)
    tier.touch(5)
    view = types.SimpleNamespace(l2_cores={2: 0.0})
    assert tier.on_contention(view)         # grant + promotion changed map
    assert tier.promoted == {5: 2}
    assert not tier.on_contention(view)     # steady state


def test_tier_quiet_then_loud_cotenant_retreats():
    """The satellite end-to-end shape at tier level: the co-tenant's core
    goes quiet (grant + promote), then wakes up (instant revoke, every
    promotion demoted)."""
    tier = _tier(quiet_threshold=0.05, hysteresis=2, color_ways=1)
    for p in range(4):
        tier.touch(p, n=8)
    quiet = {0: 0.0, 1: 4.5}
    tier.step_interval(quiet)
    tier.step_interval(quiet)
    assert tier.granted == [0] and len(tier.promoted) > 0
    loud = {0: 4.5, 1: 4.5}                 # co-tenant woke up
    assert tier.step_interval(loud) == {}
    assert tier.granted == [] and tier.promoted == {}
    assert tier.stats.core_revocations == 1
    assert tier.stats.demotions > 0


# ---------------------------------------------------------------------------
# satellite regression: the repair fallback is hierarchy-gated, not faked
# ---------------------------------------------------------------------------

def _route_alias_suspects(monkeypatch, plat_name):
    """Force repair_sets' sanity round to refute a survivor-rich
    reassembly and record whether the group-testing fallback ran."""
    plat = get_platform(plat_name)
    host, vm = plat.make_host_vm(seed=3, with_noise=False)
    vev = VEV(vm)
    ways = plat.l2.n_ways
    pool = np.arange(64, 64 * (2 * ways + 2), 64, dtype=np.int64)
    es = EvictionSet(gvas=pool[:ways], offset=0, level="l2",
                     spares=pool[ways:])
    monkeypatch.setattr(VEV, "_verdict_round",
                        lambda self, tests, vcpus, level:
                        [True] * len(tests))
    monkeypatch.setattr(VEV, "validate_sets",
                        lambda self, sets, level, vcpus=None:
                        [False] * len(sets))
    calls = []
    monkeypatch.setattr(
        eviction_mod, "build_many",
        lambda vm_, jobs, *a, **kw: (calls.append(len(jobs))
                                     or ([[] for _ in jobs], [], [])))
    out = vev.repair_sets([es], valid=np.asarray([False]), level="l2",
                          ways=ways)
    return out, calls


def test_milan_aliasing_routes_suspects_to_group_test(monkeypatch):
    """On milan_ccx the hierarchy model says a refuted survivor-rich
    reassembly can be directory aliasing measured -> the classic
    group-testing prune gets the suspects (this used to be a hard-coded
    platform-name fake; it now keys off `directory_aliasing`)."""
    out, calls = _route_alias_suspects(monkeypatch, "milan_ccx")
    assert calls == [1]                     # fallback ran on the suspect
    assert out.failed == [0]                # (stubbed build found nothing)


def test_non_aliasing_platform_fails_suspects_without_group_test(monkeypatch):
    """Where the model rules aliasing out (skylake_sp: 512-set LLC over a
    256-set L2), the same refuted reassembly is plain unrecoverable
    drift: straight to `failed`, no fallback dispatches spent."""
    out, calls = _route_alias_suspects(monkeypatch, "skylake_sp")
    assert calls == []
    assert out.failed == [0]


# ---------------------------------------------------------------------------
# the closed fleet loop: harvest on vs off
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def harvest_pair():
    """(on, off) reports of the L2-harvest scenario on skylake_sp: a
    targeted co-tenant thrashes the sensitive task's private-L2 working
    set; harvest=on lets CAP's L2 tier promote it into a measured-quiet
    sibling L2."""
    return {h: run_fleet("skylake_sp", policy="cas", cap="on", seed=0,
                         harvest=h)
            for h in ("on", "off")}


def test_fleet_harvest_improves_residual_ws_latency(harvest_pair):
    on, off = harvest_pair["on"], harvest_pair["off"]
    assert (on.harvest, off.harvest) == ("on", "off")
    assert on.harvest_intervals > 0 and on.harvest_grants >= 1
    assert on.harvest_promotions > 0
    # the promoted working set survives the co-tenant window: residual
    # latency drops, fleet throughput does not regress
    assert on.ws_lat_cycles < off.ws_lat_cycles
    assert on.throughput >= off.throughput
    # the grant was measurement-justified: the harvested core's measured
    # L2 rate stayed under the fleet's quiet threshold
    assert on.l2_quiet_rate <= 0.25


def test_harvest_summary_reports_the_delta(harvest_pair):
    row = harvest_summary(list(harvest_pair.values()))["skylake_sp"]
    assert row["lat_improvement"] > 0.05
    assert row["ws_lat_on"] < row["ws_lat_off"]
    assert row["harvest_intervals"] > 0
