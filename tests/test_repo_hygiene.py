"""Repository hygiene: no tracked bytecode, ever.

PR 3 accidentally committed ``__pycache__``/``*.pyc`` files; they are
purged, ``.gitignore`` covers them, and this test (plus the equivalent CI
step) fails if any tracked path regresses.  Skips gracefully when git (or
the repo metadata) is unavailable, e.g. in an sdist.
"""

import os
import subprocess

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tracked_files():
    try:
        out = subprocess.run(["git", "ls-files"], cwd=REPO, timeout=60,
                             capture_output=True, text=True)
    except (OSError, subprocess.TimeoutExpired):
        pytest.skip("git unavailable")
    if out.returncode != 0:
        pytest.skip("not a git checkout")
    return out.stdout.splitlines()


def test_no_tracked_bytecode_or_pycache():
    bad = [f for f in _tracked_files()
           if f.endswith(".pyc") or "__pycache__" in f.split("/")]
    assert not bad, f"tracked bytecode paths: {bad}"


def test_gitignore_covers_bytecode():
    with open(os.path.join(REPO, ".gitignore")) as f:
        rules = f.read()
    assert "__pycache__/" in rules
    assert "*.py[cod]" in rules
