"""Pod backend: SimPod determinism, session parity with the LLC surface,
plan cost/fusion, the rebalance/expert/router consumers, and the closed
pod loop (probe → tier → reroute/rebalance → measured p99 + step time).

Mirrors `test_abstraction.py`'s attach→query→export→import coverage on
the pod target, plus the ISSUE-9 satellite regressions (`vmem_probe`
except-narrowing + aligned search; `ReplicaRouter` release path).
"""

import json

import numpy as np
import pytest

from repro.core import (CacheXSession, StaleAbstractionError, get_backend,
                        list_backends, plan_cost)
from repro.core.probeplan import execute, fuse, split_result
from repro.tpuprobe.pod_backend import (NOMINAL_HBM_LAT, PodFleetSim,
                                        PodScan, PodSession, SimPod,
                                        apply_ici, apply_vmem,
                                        degraded_hops, ici_plan,
                                        run_pod_loop, vmem_plan)
from repro.tpuprobe.vmem_probe import NOMINAL_VMEM, probe_effective_vmem


def make_pod(**kw):
    kw.setdefault("mesh_shape", {"data": 2, "model": 4})
    kw.setdefault("seed", 7)
    kw.setdefault("reserved_vmem", (3 << 20) + 12345)
    return SimPod(**kw)


# -- SimPod / PodSlice ----------------------------------------------------------


def test_simpod_deterministic_under_fixed_seed():
    def run():
        pod = make_pod(hbm_schedule=lambda c, t: 1.0 + 0.2 * c)
        s = PodSession.attach(pod.slice(), eager=True)
        for _ in range(5):
            s.refresh()
        return s.export()

    a, b = run(), run()
    assert a == b
    # a different seed perturbs the timer jitter stream
    pod = make_pod(seed=8, hbm_schedule=lambda c, t: 1.0 + 0.2 * c)
    s = PodSession.attach(pod.slice(), eager=True)
    for _ in range(5):
        s.refresh()
    assert s.export()["scan"]["ewma"] != a["scan"]["ewma"]


def test_slice_counts_probe_work():
    pod = make_pod()
    sl = pod.slice()
    PodSession.attach(sl, eager=True)
    assert sl.stat_dispatches > 0 and sl.stat_accesses > 0


# -- the probes as plans --------------------------------------------------------


def test_vmem_plan_matches_oracle_and_alignment():
    pod = make_pod(reserved_vmem=(5 << 20) + 777)
    plan = vmem_plan(range(pod.n_chips))
    eff = apply_vmem(plan, execute(pod.slice(), plan))
    align = plan.meta["align"]
    expected = ((NOMINAL_VMEM - pod.reserved_vmem) // align) * align
    assert set(eff) == set(range(pod.n_chips))
    for budget in eff.values():
        assert budget == expected
        assert budget % align == 0
        # maximal: one more quantum would exceed the hidden budget
        assert budget + align > NOMINAL_VMEM - pod.reserved_vmem


def test_vmem_plan_is_one_dispatch_per_vote():
    plan = vmem_plan(range(8), votes=1)
    assert plan.signature() == ("WarmTimer", "Vote[vmem]")
    assert plan.n_dispatches == 1


def test_ici_plan_isolates_degraded_hop():
    pod = make_pod(link_schedule=lambda ax, hop, t: 2.0
                   if (ax == "model" and hop == 2) else 1.0)
    plan = ici_plan(pod.mesh_shape)
    stats = apply_ici(plan, execute(pod.slice(), plan))
    assert set(stats) == {"data", "model"}
    assert stats["model"]["slowdown"] > stats["data"]["slowdown"]
    assert degraded_hops(stats, "model", threshold=1.3) == [2]
    assert degraded_hops(stats, "data", threshold=1.3) == []
    # per-axis ops carry their axis as the level tag (PR 8 plumbing)
    assert plan.signature() == ("WarmTimer", "Measure[ici_data]",
                                "Measure[ici_model]")


def test_pod_plans_cost_and_fuse():
    pod = make_pod()
    s = PodSession.attach(pod.slice())
    plan = s.plan()
    cost = plan_cost(plan)
    assert cost.dispatches == plan.n_dispatches
    fused, spans = fuse([plan, s.plan()])
    res = split_result(execute(pod.slice(), fused), spans)
    assert len(res) == 2
    assert len(res[0].values[2]) == pod.n_chips


# -- the monitor (PodScan) ------------------------------------------------------


def test_podscan_tiers_commit_with_hysteresis():
    pod = make_pod(hbm_schedule=lambda c, t: 2.0 if c == 3 else 1.0)
    scan = PodScan(pod.slice(), ewma_alpha=1.0)
    for i in range(4):
        scan.monitor_once()
        committed = scan.tiers.tier[3]
        assert committed == (2 if i >= 2 else 0)   # 3-interval commit
    assert scan.tiers.tier[0] == 0


def test_podscan_quarantines_faulted_chip_and_confirms_clean():
    state = {"broken": True}

    def schedule(c, t):
        return 8.0 if (c == 1 and state["broken"]) else 1.0

    pod = make_pod(hbm_schedule=schedule)
    s = PodSession.attach(pod.slice())
    drifts = []
    s.subscribe_drift(drifts.append)
    for _ in range(3):
        s.refresh()
    scan = s.monitored_sets()
    assert scan.flagged == {1}
    assert len(drifts) == 1 and drifts[0].kind == "pod_chip"
    assert drifts[0].set_indices == [1]
    assert s.check_drift()["flagged"] == [1]
    state["broken"] = False
    s.refresh()
    assert scan.confirm_clean([1]) == [1]
    assert scan.flagged == set()


# -- session surface parity -----------------------------------------------------


def test_backend_registry_dispatch():
    assert "llc" in list_backends() and "pod" in list_backends()
    assert get_backend("pod").name == "pod"
    with pytest.raises(KeyError):
        get_backend("gpu")
    pod = make_pod()
    s = CacheXSession.attach(pod.slice(), "pod", backend="pod")
    assert isinstance(s, PodSession)


def test_pod_session_serves_the_session_surface():
    pod = make_pod(hbm_schedule=lambda c, t: 1.0 + 0.1 * c)
    s = CacheXSession.attach(pod.slice(), "pod", backend="pod", eager=True)
    topo = s.topology()
    assert topo.axes == pod.mesh_shape and topo.n_chips == 8
    assert set(topo.effective_vmem) == set(range(8))
    colors = s.colors()
    assert colors.n_zones == 16
    assert colors.chip_of(colors.zone_of(5, "vmem")) == 5
    view = s.contention()
    assert set(view.per_domain) == set(range(8))
    assert set(view.per_color) == set(range(16))
    assert "hbm" in view.per_level and "ici:model" in view.per_level
    seen = []
    tok = s.subscribe(seen.append)
    s.refresh()
    assert len(seen) == 1 and seen[0].interval > view.interval
    s.unsubscribe(tok)
    s.refresh()
    assert len(seen) == 1
    assert s.validate()["vmem_ok"] and s.validate()["link_ok"]


def test_pod_export_import_roundtrip_and_staleness():
    pod = make_pod()
    s = PodSession.attach(pod.slice(), eager=True)
    for _ in range(3):
        s.refresh()
    js = s.export_json()
    data = json.loads(js)
    assert data["format"] == "cachex-pod-abstraction/v1"

    # restore on a fresh slice: no re-probe, identical answers
    s2 = PodSession.import_json(pod.slice(), js)
    assert s2.topology().effective_vmem == s.topology().effective_vmem
    assert s2.export() == s.export()
    # CacheXSession.import_ routes pod-format snapshots to the backend
    s3 = CacheXSession.import_(pod.slice(), data)
    assert isinstance(s3, PodSession)

    # reprovisioning bumps the pod epoch -> snapshot is stale
    pod.reprovision(reserved_vmem=6 << 20)
    with pytest.raises(StaleAbstractionError):
        PodSession.import_json(pod.slice(), js)
    s4 = PodSession.import_json(pod.slice(), js, allow_stale=True)
    rep = s4.repair()
    assert rep["epoch"] == s4.epoch and rep["vmem_changed"]
    assert s4.validate()["vmem_ok"]


def test_llc_import_still_rejects_garbage():
    from repro.core import get_platform
    plat = get_platform("skylake_sp")
    _host, vm = plat.make_host_vm(seed=0, with_noise=False)
    with pytest.raises(ValueError):
        CacheXSession.import_(vm, {"format": "not-a-format"})


# -- seed consumers on the session ---------------------------------------------


def test_expert_rebalancer_moves_only_after_tier_commit():
    from repro.distributed.rebalance import ExpertRebalancer
    from repro.core.abstraction import ContentionView

    def view(rates):
        return ContentionView(per_domain=rates, per_color={}, mean_rate=0.0,
                              window_ms=10.0, measured_at_ms=0.0, interval=0)

    reb = ExpertRebalancer(8, 4, experts_per_device=2,
                           thresholds=(1.15, 1.5))
    reb.update_load(np.array([8, 7, 6, 5, 4, 3, 2, 1], float))
    before = reb.placement.expert_to_device.copy()
    hot = {0: 1.0, 1: 1.0, 2: 2.0, 3: 1.0}
    for _ in range(2):
        reb.on_contention(view(hot))
        assert np.array_equal(reb.placement.expert_to_device, before)
        assert reb.moves == 0
    reb.on_contention(view(hot))           # third interval: tier commits
    assert reb.moves > 0 and reb.rebalances == 1
    # the heaviest expert no longer sits on the contended device
    heaviest = int(np.argmax(reb.load))
    assert reb.placement.expert_to_device[heaviest] != 2


def test_straggler_mitigator_consumes_contention_views():
    from repro.distributed.rebalance import StragglerMitigator
    from repro.core.abstraction import ContentionView
    m = StragglerMitigator(4, 16)
    v = ContentionView(per_domain={0: 1.0, 1: 1.0, 2: 3.0, 3: 1.0},
                       per_color={}, mean_rate=0.0, window_ms=10.0,
                       measured_at_ms=0.0, interval=0)
    for _ in range(3):
        plan = m.on_contention(v)
    assert plan[2] < plan[0] and plan.sum() == 16


def test_staging_pool_follows_pod_colors():
    from repro.data.pipeline import ColoredStagingPool
    pod = make_pod(hbm_schedule=lambda c, t: 3.0 if c == 0 else 1.0)
    s = PodSession.attach(pod.slice(), eager=True)
    pool = ColoredStagingPool.from_colors(s.colors(), bufs_per_zone=2)
    assert set(pool.cap.free_lists) == set(range(16))
    s.subscribe(pool.on_contention)
    for _ in range(4):
        s.refresh()
    h = pool.stage(np.zeros(4))
    # CAP places staging in the hottest zone: chip 0's HBM arena (zone 0)
    assert h[0] == s.colors().zone_of(0, "hbm")
    pool.release(h)


# -- ReplicaRouter release path (satellite regression) --------------------------


def test_router_drained_replica_becomes_routable_again():
    from repro.serve.engine import ReplicaRouter, Request
    r = ReplicaRouter(2)
    reqs = [Request(rid=i, prompt=np.zeros(1, np.int32)) for i in range(4)]
    for q in reqs:
        r.assign(q)
    assert list(r.load) == [2, 2]
    # drain replica 0 only: it must become the preferred target again
    for q in reqs:
        if q.replica == 0:
            r.complete(q)
    assert list(r.load) == [0, 2]
    assert r.route() == 0
    # completion is idempotent per request; double-release is an error
    assert reqs[0].replica is None
    r.complete(reqs[0])                     # no-op
    with pytest.raises(ValueError):
        r.release(0)
        r.release(0)
        r.release(0)


def test_serve_engine_releases_router_load():
    from repro.core.cas import TierTracker
    from repro.serve.engine import ReplicaRouter, Request, ServeEngine
    from repro.configs.base import get_config, reduced_config
    from repro.models import lm
    import jax
    cfg = reduced_config(get_config("qwen1p5_0p5b"))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    router = ReplicaRouter(2, tiers=TierTracker(keys=[0, 1]))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=16,
                      router=router)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=np.array([1, 2], np.int32),
                           max_new=2))
    assert router.load.sum() == 3
    eng.run_until_drained()
    assert list(router.load) == [0, 0]


# -- vmem_probe satellite regression --------------------------------------------


def test_probe_effective_vmem_alignment_and_maximality():
    align = 1 << 18
    for reserved in (2 << 20, (3 << 20) + 1, (6 << 20) + align - 1):
        eff = probe_effective_vmem(reserved_model=reserved)
        true_budget = NOMINAL_VMEM - reserved
        assert eff % align == 0
        assert eff <= true_budget           # never over-claims
        assert eff + align > true_budget    # largest aligned fit
    assert probe_effective_vmem(reserved_model=NOMINAL_VMEM) == 0


def test_tile_fits_narrowed_except(monkeypatch):
    """Real bugs must propagate; only compile rejections mean "no fit"."""
    import repro.kernels.cache_probe.kernel as kmod
    from repro.tpuprobe.vmem_probe import _tile_fits_tpu

    def boom(*a, **kw):
        raise TypeError("a real bug, not an over-budget tile")

    monkeypatch.setattr(kmod, "triad", boom)
    with pytest.raises(TypeError):
        _tile_fits_tpu(1 << 20)

    def over_budget(*a, **kw):
        raise ValueError("tile does not fit")

    monkeypatch.setattr(kmod, "triad", over_budget)
    assert _tile_fits_tpu(1 << 20) is False


# -- the closed pod loop --------------------------------------------------------


@pytest.mark.slow
def test_closed_loop_rebalance_improves_p99_and_step_time():
    on = run_pod_loop(rebalance="on", seed=0)
    off = run_pod_loop(rebalance="off", seed=0)
    assert on.requests == off.requests > 0
    assert on.p99_decode_ms < off.p99_decode_ms
    assert on.mean_step_s < off.mean_step_s
    assert on.rebalances > 0 and on.expert_moves > 0
    assert off.rebalances == 0 and off.expert_moves == 0
    # routing actually avoided the hot chip after tier commit
    assert on.hot_request_frac < off.hot_request_frac


def test_closed_loop_router_prefers_quiet_tier_e2e():
    sim = PodFleetSim(intervals=12, warmup=6, rebalance="on")
    report = sim.run()
    assert report.hot_request_frac == 0.0
    assert sim.router.tiers.tier[sim.hot_chip] > 0
    assert list(sim.router.load) == [0] * sim.pod.n_chips   # all released
