"""Closed-loop fleet simulator tests (paper §4 / §6.3-6.4 / Fig 10).

The expensive end-to-end properties run on one platform (skylake_sp); the
full 6-platform sweep lives in `benchmarks --only fleet`.  The progress
kernel and the summary reducers are covered by fast pure-function tests.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fleet import (FleetReport, FleetSim, default_workloads,
                              fig10_summary, fleet_interval_progress,
                              run_fleet, speedup_summary)
from repro.core.platforms import get_platform


# ---------------------------------------------------------------------------
# vectorized progress / contention-accounting kernel
# ---------------------------------------------------------------------------

def test_progress_kernel_matches_numpy_reference():
    rng = np.random.default_rng(3)
    B, D, T = 5, 3, 24
    domain_idx = rng.integers(0, D, B).astype(np.int32)
    rates = rng.uniform(5, 50, B)
    period = rng.integers(2, 9, B).astype(np.int32)
    duty_on = np.minimum(rng.integers(1, 9, B), period).astype(np.int32)
    sens = rng.uniform(0, 2, B)
    ipc0 = rng.uniform(0.5, 1.5, B)
    slow = rng.uniform(1, 3, B)
    noise = rng.uniform(0, 300, D)
    scale = 0.01

    prog, cont = fleet_interval_progress(
        jnp.asarray(domain_idx), jnp.asarray(rates), jnp.asarray(period),
        jnp.asarray(duty_on), jnp.asarray(sens), jnp.asarray(ipc0),
        jnp.asarray(slow), jnp.asarray(noise), scale,
        n_domains=D, ticks=T)

    ref_prog = np.zeros(B)
    ref_cont = np.zeros((D, T))
    for t in range(T):
        traffic = noise.copy()
        for w in range(B):
            if t % period[w] < duty_on[w]:
                traffic[domain_idx[w]] += rates[w]
        ref_cont[:, t] = traffic * scale
        for w in range(B):
            c = ref_cont[domain_idx[w], t]
            ref_prog[w] += ipc0[w] / ((1 + sens[w] * c) * slow[w])
    np.testing.assert_allclose(np.asarray(prog), ref_prog, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(cont), ref_cont.mean(axis=1),
                               rtol=1e-5)


def test_progress_kernel_contention_hurts_sensitive_more():
    """The Fig 2a shape: same traffic, higher sensitivity => less work."""
    kw = dict(n_domains=1, ticks=16)
    args = (jnp.zeros(2, jnp.int32), jnp.zeros(2), jnp.ones(2, jnp.int32),
            jnp.ones(2, jnp.int32), jnp.array([0.1, 2.0]), jnp.ones(2),
            jnp.ones(2), jnp.array([400.0]), 0.01)
    prog, _ = fleet_interval_progress(*args, **kw)
    assert float(prog[0]) > float(prog[1])


# ---------------------------------------------------------------------------
# closed loop, end to end (one platform)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fleet_pair():
    """(eevdf, cas) reports on skylake_sp, CAP on, shared across tests."""
    return {pol: run_fleet("skylake_sp", policy=pol, cap="on", seed=0)
            for pol in ("eevdf", "cas")}


def test_cas_steers_sensitive_task_to_quiet_domain(fleet_pair):
    """Fig 10, closed-loop: CAS discovers the polluted domain from VSCAN's
    *measured* rates and steers the fleet away; EEVDF affinity pins it."""
    assert fleet_pair["cas"].quiet_residency >= 0.8
    assert fleet_pair["eevdf"].quiet_residency <= 0.2
    assert fleet_pair["cas"].throughput > 1.2 * fleet_pair["eevdf"].throughput


def test_measured_rates_separate_domains(fleet_pair):
    """The decision inputs are real measurements: the polluted domain's
    VSCAN rate must dominate the quiet domain's, and the committed tiers
    must rank the quiet domain better."""
    for r in fleet_pair.values():
        assert r.hot_rate > 2 * r.quiet_rate
        assert r.tiers[0] > r.tiers[1]


def test_cap_protects_working_set():
    """Table 8 analog: with CAP off, the vanilla mixed-color page-cache
    stream evicts the sensitive working set (latency -> DRAM); CAP confines
    the stream to the measured-hottest color and throughput rises."""
    on = run_fleet("skylake_sp", policy="cas", cap="on", seed=0)
    off = run_fleet("skylake_sp", policy="cas", cap="off", seed=0)
    assert on.ws_lat_cycles < 0.5 * off.ws_lat_cycles
    assert on.throughput > off.throughput
    assert on.cap_allocated > 0 and on.reclaims > 0
    assert off.cap_allocated == 0


def test_fleet_report_row_contract(fleet_pair):
    """Headered machine-readable CSV: columns come straight from the
    dataclass fields, so they cannot silently drift."""
    import csv
    import dataclasses
    import io
    header = FleetReport.csv_header().split(",")
    assert header == [f.name for f in dataclasses.fields(FleetReport)]
    row = fleet_pair["cas"].csv_row()
    cells = next(csv.reader(io.StringIO(row)))
    assert len(cells) == len(header)
    assert cells[:3] == ["skylake_sp", "cas", "on"]


def test_fleet_view_widens_topology():
    sim_plat = FleetSim("icelake_sp", n_intervals=0).plat
    base = get_platform("icelake_sp")
    assert sim_plat.n_domains >= 2
    assert (sim_plat.cores_per_domain
            >= max(base.cores_per_domain, len(default_workloads())))
    assert sim_plat.llc == base.llc and sim_plat.provisioning == base.provisioning


# ---------------------------------------------------------------------------
# summary reducers (pure functions over synthetic reports)
# ---------------------------------------------------------------------------

def _report(platform, policy, cap, thr, res):
    return FleetReport(
        platform=platform, policy=policy, cap=cap, seed=0, n_intervals=10,
        warmup=4, throughput=thr, per_workload={}, quiet_residency=res,
        hot_rate=5.0, quiet_rate=0.5, tiers={0: 2, 1: 0}, ws_lat_cycles=14.0,
        recolor_events=0, reclaims=0, cap_allocated=0, dispatches=0,
        accesses=0, wall_s=0.0)


def test_fig10_and_speedup_summaries():
    reports = []
    for plat, cas_res in (("a", 1.0), ("b", 0.2)):
        reports += [
            _report(plat, "eevdf", "on", 100.0, 0.0),
            _report(plat, "rusty", "on", 110.0, 0.1),
            _report(plat, "cas", "on", 200.0, cas_res),
            _report(plat, "cas", "off", 160.0, cas_res),
        ]
    f10 = fig10_summary(reports)
    assert f10["n_platforms"] == 2
    assert f10["cas_quiet"] == 1          # only platform "a"
    assert f10["eevdf_pinned"] == 2
    assert f10["separated"] == 1
    assert f10["residency"]["a"]["cas"] == 1.0

    sp = speedup_summary(reports)
    assert sp["a"]["cas_vs_eevdf"] == pytest.approx(1.0)
    assert sp["a"]["cas_vs_rusty"] == pytest.approx(200 / 110 - 1)
    assert sp["a"]["cap_on_vs_off"] == pytest.approx(0.25)
