"""VCOL tests: paper §3.2, Table 4, Fig 3b, Fig 9 behaviours."""

import numpy as np
import pytest

from repro.core.color import VCOL, color_accuracy, gpa_color_spread
from repro.core.eviction import VEV
from tests.conftest import make_vm, N_COLORS


@pytest.fixture(scope="module")
def vcol_setup():
    host, vm = make_vm(mapping="fragmented", seed=3)
    vcol = VCOL(vm)
    cf = vcol.build_color_filters(n_colors=N_COLORS, ways=8, seed=11)
    return host, vm, vcol, cf


def test_filter_count_and_distinct_colors(vcol_setup):
    host, vm, vcol, cf = vcol_setup
    assert cf.n_colors == N_COLORS
    true_colors = [vm.hypercall_l2_color(int(es.gvas[0]) >> 12) % N_COLORS
                   for es in cf.filters]
    assert len(set(true_colors)) == N_COLORS
    # replicated filters sit at distinct aligned offsets
    assert len(set(int(o) for o in cf.offsets)) == N_COLORS
    assert all(int(o) % 64 == 0 for o in cf.offsets)


def test_parallel_filtering_100pct_accuracy(vcol_setup):
    """Paper §6.2: '100% correct color identification' (via hypercall)."""
    host, vm, vcol, cf = vcol_setup
    pages = vm.alloc_pages(96)
    colors = vcol.identify_colors_parallel(cf, pages)
    assert color_accuracy(vm, pages, colors, N_COLORS) == 1.0
    vm.free_pages(pages)


def test_parallel_matches_sequential(vcol_setup):
    host, vm, vcol, cf = vcol_setup
    pages = vm.alloc_pages(24)
    par = vcol.identify_colors_parallel(cf, pages)
    seq = np.array([vcol.identify_color_sequential(cf, int(p))
                    for p in pages])
    assert np.array_equal(par, seq)
    vm.free_pages(pages)


def test_parallel_filtering_is_cheaper(vcol_setup):
    """Table 4: parallel filtering does ~n_colors x fewer passes."""
    host, vm, vcol, cf = vcol_setup
    pages = vm.alloc_pages(32)
    before = vm.stat_passes
    vcol.identify_colors_parallel(cf, pages)
    par_passes = vm.stat_passes - before
    before = vm.stat_passes
    for p in pages:
        vcol.identify_color_sequential(cf, int(p))
    seq_passes = vm.stat_passes - before
    assert par_passes * 4 < seq_passes
    vm.free_pages(pages)


def test_free_lists_partition_pages(vcol_setup):
    host, vm, vcol, cf = vcol_setup
    pages = vm.alloc_pages(64)
    lists = vcol.build_free_lists(cf, pages)
    got = sorted(p for lst in lists.values() for p in lst)
    assert got == sorted(int(p) for p in pages)
    vm.free_pages(pages)


def test_gpa_color_unreliable_under_fragmentation():
    """Fig 3b: with fragmented backing, one GPA color spreads over many HPA
    colors; with contiguous backing it maps to a single HPA color."""
    _, vm_frag = make_vm(mapping="fragmented", seed=7)
    _, vm_cont = make_vm(mapping="contiguous", seed=7)
    pages = np.arange(256)
    spread_frag = gpa_color_spread(vm_frag, pages, N_COLORS)
    spread_cont = gpa_color_spread(vm_cont, pages, N_COLORS)
    for g, hist in spread_cont.items():
        assert (hist > 0).sum() == 1     # contiguous: GPA color == HPA color
    assert any((hist > 0).sum() >= 3 for hist in spread_frag.values())


def test_remap_breaks_virtual_colors_and_rebuild_restores():
    """Fig 9: hypervisor page remapping invalidates virtual colors; vcol
    rebuild (new filters + refiltering) restores 100% accuracy."""
    host, vm = make_vm(mapping="contiguous", seed=9)
    vcol = VCOL(vm)
    cf = vcol.build_color_filters(n_colors=N_COLORS, ways=8, seed=13)
    pages = vm.alloc_pages(48)
    colors_before = vcol.identify_colors_parallel(cf, pages)
    assert color_accuracy(vm, pages, colors_before, N_COLORS) == 1.0
    # hypervisor silently remaps 60% of guest pages
    vm._page_table = host.remap_pages(vm._page_table, 0.6)
    acc_stale = color_accuracy(vm, pages, colors_before, N_COLORS)
    assert acc_stale < 1.0
    # rebuild color filters and refilter -> accuracy restored
    vcol2 = VCOL(vm)
    cf2 = vcol2.build_color_filters(n_colors=N_COLORS, ways=8, seed=14)
    colors_after = vcol2.identify_colors_parallel(cf2, pages)
    assert color_accuracy(vm, pages, colors_after, N_COLORS) == 1.0
