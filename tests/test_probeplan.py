"""ProbePlan IR + executor tests (the api_redesign tentpole).

Covers:
  * executor unit semantics — Commit segment fusion (one dispatch,
    state-identical to per-segment traversals), Measure lane trimming,
    Vote majority verdicts vs the pre-plan `_majority_verdicts` reference,
    Wait/WarmTimer side effects;
  * plan fusion — `fuse` merges structurally congruent plans into one
    program sharing dispatches and `split_result` restores per-plan
    outputs bit for bit;
  * `execute_many` — G guests' plans as one vectorized program: shapes
    with heterogeneous lane counts, bit-identical per-guest results and
    machine states vs single-guest execution, congruence/shared-host
    guards;
  * plan-vs-legacy parity, property-style: the whole VEV/VCOL/VSCAN
    pipeline (`run_cachex`) with `use_plans=True` must reproduce the
    pre-redesign path's report field for field on every platform (tier-1:
    skylake_sp; rest `slow`), and the closed-loop fleet must reproduce its
    reports across legacy / plan / lockstep execution while the lockstep
    matrix issues >= 2x fewer physical probe dispatches per tick.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import cachesim, probeplan
from repro.core.abstraction import ProbeConfig
from repro.core.eviction import VEV, _majority_verdicts, _probe_lanes
from repro.core.host_model import probe_dispatch_count
from repro.core.platforms import get_platform, list_platforms
from repro.core.probeplan import (Commit, Measure, PlanLowering, ProbePlan,
                                  Segment, Vote, Wait, WarmTimer)
from repro.core.runner import run_cachex
from tests.conftest import make_vm

FAST_PLATFORM = "skylake_sp"


def _matrix_params():
    return [name if name == FAST_PLATFORM
            else pytest.param(name, marks=pytest.mark.slow)
            for name in list_platforms()]


def _twin_vms(n=2, seed=7, **kw):
    """n identically-booted (host, vm) pairs: same seeds => same hidden
    page tables and machine states, so state evolutions are comparable."""
    return [make_vm(seed=seed, **kw) for _ in range(n)]


def _states_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# executor units
# ---------------------------------------------------------------------------

def test_commit_fuses_segments_into_one_dispatch():
    (h1, vm1), (h2, vm2) = _twin_vms()
    pages = vm1.alloc_pages(8)
    vm2.alloc_pages(8)                      # twin allocator stays in sync
    seg_a = np.array([vm1.gva(int(p), 0) for p in pages[:4]])
    seg_b = np.array([vm1.gva(int(p), 64) for p in pages[4:]])
    plan = ProbePlan(ops=(Commit(segments=(Segment(seg_a, 0),
                                           Segment(seg_b, 1))),))
    probeplan.execute(vm1, plan)
    assert vm1.stat_passes == 1             # both segments, ONE dispatch
    # reference: per-segment committed traversals on the twin
    vm2.access(seg_a, vcpu=0)
    vm2.access(seg_b, vcpu=1)
    assert vm2.stat_passes == 2
    # same machine end state (padding no-ops only shift the LRU clock,
    # compare the tag arrays which encode all cache contents)
    _states_equal(h1.state["l2"][0], h2.state["l2"][0])
    _states_equal(h1.state["llc"][0], h2.state["llc"][0])


def test_commit_unfused_hint_keeps_per_segment_dispatches():
    host, vm = make_vm(seed=9)
    pages = vm.alloc_pages(4)
    segs = tuple(Segment(np.array([vm.gva(int(p), 0)]), 0) for p in pages)
    plan = ProbePlan(ops=(Commit(segments=segs),),
                     hints=PlanLowering(fuse_commits=False))
    probeplan.execute(vm, plan)
    assert vm.stat_passes == len(segs)      # legacy one-per-segment route


def test_wait_and_warm_ops_drive_vm_side_effects():
    host, vm = make_vm(seed=11)
    t0 = host.time_ms
    probeplan.execute(vm, ProbePlan(ops=(Wait(ms=5.0), WarmTimer())))
    assert host.time_ms == t0 + 5.0
    assert vm._timer_warm == vm.timer_warm_reads


def test_measure_returns_trimmed_per_lane_latencies():
    host, vm = make_vm(seed=13)
    pages = vm.alloc_pages(6)
    lanes = tuple(np.array([vm.gva(int(p), 0) for p in pages[:n]])
                  for n in (1, 4, 6))
    res = probeplan.execute(vm, ProbePlan(
        ops=(WarmTimer(), Measure(lanes=lanes, vcpus=(0, 0, 0))),))
    assert [len(l) for l in res.last] == [1, 4, 6]
    assert vm.stat_passes == 1


def test_vote_matches_pre_plan_majority_verdicts():
    """The executor's Vote lowering must reach exactly the verdicts of the
    pre-plan `_majority_verdicts` reference on identical tests (LRU:
    measurement lanes are uncommitted, so back-to-back runs see the same
    snapshot)."""
    host, vm = make_vm(seed=15)
    vev = VEV(vm, use_plans=False)
    pages = vm.alloc_pages(256)
    target = vm.gva(int(pages[0]), 0)
    key = vm.hypercall_llc_setslice(target)
    cong = [vm.gva(int(p), 0) for p in pages[1:]
            if vm.hypercall_llc_setslice(vm.gva(int(p), 0)) == key]
    other = [vm.gva(int(p), 0) for p in pages[1:]
             if vm.hypercall_llc_setslice(vm.gva(int(p), 0)) != key]
    ways = host.geom.llc.n_ways
    tests = [(target, np.array(cong[:ways + 2])),
             (target, np.array(other[:2 * ways]))]
    thr = VEV._threshold("llc")
    ref = _majority_verdicts(vm, _probe_lanes(tests, 1), 0, thr, votes=3)
    plan = ProbePlan(ops=(Vote(lanes=tuple(_probe_lanes(tests, 1)),
                               vcpus=(0, 0), threshold=thr, votes=3),))
    got = probeplan.execute(vm, plan).last
    np.testing.assert_array_equal(ref, got)
    assert list(got) == [True, False]


# ---------------------------------------------------------------------------
# fusion
# ---------------------------------------------------------------------------

def test_fuse_and_split_roundtrip_shares_dispatches():
    (h1, vm1), (h2, vm2) = _twin_vms(seed=17)
    pages = vm1.alloc_pages(64)
    vm2.alloc_pages(64)
    thr = VEV._threshold("llc")

    def plans_for(vm):
        lanes = [np.array([vm.gva(int(p), 0) for p in pages[a:b]])
                 for a, b in ((0, 20), (20, 44), (44, 64))]
        return [ProbePlan(ops=(Vote(lanes=(l,), vcpus=(0,),
                                    threshold=thr, votes=2),))
                for l in lanes]

    fused, spans = probeplan.fuse(plans_for(vm1))
    assert fused.signature() == ("Vote",)
    split = probeplan.split_result(probeplan.execute(vm1, fused), spans)
    assert vm1.stat_passes == 2             # one dispatch per vote, fused
    singles = [probeplan.execute(vm2, p) for p in plans_for(vm2)]
    assert vm2.stat_passes == 6             # 3 plans x 2 votes, unfused
    for s, r in zip(split, singles):
        np.testing.assert_array_equal(s.last, r.last)


def test_fuse_rejects_structural_mismatch():
    lane = (np.array([1, 2]),)
    vote = ProbePlan(ops=(Vote(lanes=lane, vcpus=(0,), threshold=1),))
    measure = ProbePlan(ops=(Measure(lanes=lane, vcpus=(0,)),))
    with pytest.raises(ValueError):
        probeplan.fuse([vote, measure])
    other = ProbePlan(ops=(Vote(lanes=lane, vcpus=(0,), threshold=1,
                                votes=5),))
    with pytest.raises(ValueError):
        probeplan.fuse([vote, other])


# ---------------------------------------------------------------------------
# execute_many: vmap over guests
# ---------------------------------------------------------------------------

def test_execute_many_matches_single_execution_bitwise():
    """G guests with *different* states and lane counts co-execute as one
    vectorized program; every guest's latencies AND committed machine state
    must equal its standalone execution (the property the fleet's lockstep
    bit-identity rests on)."""
    seeds = (21, 22, 23)
    joint = [make_vm(seed=s) for s in seeds]
    solo = [make_vm(seed=s) for s in seeds]

    def plan_for(vm, n_lanes):
        pages = vm.alloc_pages(16)
        prime = np.array([vm.gva(int(p), 0) for p in pages])
        lanes = tuple(np.array([vm.gva(int(p), 64) for p in pages[:2 + i]])
                      for i in range(n_lanes))
        return ProbePlan(ops=(Commit(segments=(Segment(prime, 0),)),
                              Wait(ms=2.0), WarmTimer(),
                              Measure(lanes=lanes,
                                      vcpus=(0,) * n_lanes)),
                         label="t.monitor")

    lane_counts = (0, 3, 5)                  # heterogeneous (incl. empty)
    jplans = [plan_for(vm, n) for (_, vm), n in zip(joint, lane_counts)]
    splans = [plan_for(vm, n) for (_, vm), n in zip(solo, lane_counts)]
    before = probe_dispatch_count()
    jres = probeplan.execute_many([vm for _, vm in joint], jplans)
    assert probe_dispatch_count() - before == 2   # Commit + Measure, fused
    sres = [probeplan.execute(vm, p) for (_, vm), p in zip(solo, splans)]
    for (jh, jvm), (sh, svm), jr, sr, n in zip(joint, solo, jres, sres,
                                               lane_counts):
        assert len(jr.last) == n
        for a, b in zip(jr.last, sr.last):
            np.testing.assert_array_equal(a, b)
        _states_equal(jh.state["l2"][0], sh.state["l2"][0])
        _states_equal(jh.state["llc"][0], sh.state["llc"][0])
        assert jh.time_ms == sh.time_ms
        # per-guest cost accounting and rng-salt sequencing must match the
        # standalone path exactly (a lane-less guest issues no measure
        # pass and keeps its _probe_seq untouched)
        assert jvm.stat_passes == svm.stat_passes
        assert jvm.stat_accesses == svm.stat_accesses
        assert jvm._probe_seq == svm._probe_seq


def test_execute_many_guards():
    (h1, vm1), (h2, vm2) = _twin_vms(seed=25)
    lane = (np.array([vm1.gva(0, 0)]),)
    vote = ProbePlan(ops=(Vote(lanes=lane, vcpus=(0,), threshold=1),))
    measure = ProbePlan(ops=(Measure(lanes=lane, vcpus=(0,)),))
    with pytest.raises(ValueError):
        probeplan.execute_many([vm1, vm2], [vote, measure])
    with pytest.raises(ValueError):          # one host per guest
        probeplan.execute_many([vm1, vm1], [measure, measure])
    with pytest.raises(ValueError):
        probeplan.execute_many([vm1], [measure, measure])
    salted = ProbePlan(ops=(Measure(lanes=lane, vcpus=(0,), salt=3),))
    with pytest.raises(ValueError):          # rng salts must agree
        probeplan.execute_many([vm1, vm2], [measure, salted])


def test_fleet_seed_unbatched_reference_keeps_per_dispatch_route():
    """`use_batch=False` is the seed per-dispatch benchmark reference:
    plans are inherently batched, so the fleet loop must fall back to the
    pre-plan route exactly like session.refresh / VScan.monitor_once do."""
    from repro.core.fleet import FleetSim
    assert not FleetSim(FAST_PLATFORM, n_intervals=0,
                        use_batch=False)._plan_route
    assert FleetSim(FAST_PLATFORM, n_intervals=0)._plan_route


def test_stack_unstack_states_roundtrip():
    (h1, _), (h2, _) = _twin_vms(seed=27)
    h2.state["clock"] = h2.state["clock"] + 7
    stacked = cachesim.stack_states([h1.state, h2.state])
    back = cachesim.unstack_states(stacked, 2)
    _states_equal(back[0], h1.state)
    _states_equal(back[1], h2.state)


# ---------------------------------------------------------------------------
# plan vs pre-redesign parity (property-style, per platform)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", _matrix_params())
def test_pipeline_plan_vs_legacy_parity(name):
    """VEV + VCOL + VSCAN + CAS/CAP through `run_cachex`: the ProbePlan
    route must reproduce the pre-redesign path's report field for field
    (everything except dispatch/wall cost — fused commits are the point)."""
    plat = get_platform(name)
    reports = {}
    for use_plans in (True, False):
        cfg = ProbeConfig.for_platform(plat, seed=3, use_plans=use_plans)
        reports[use_plans] = run_cachex(plat, monitor_intervals=2,
                                        config=cfg)
    a, b = reports[True], reports[False]
    for f in dataclasses.fields(type(a)):
        if f.name in ("dispatches", "wall_s"):
            continue
        assert getattr(a, f.name) == getattr(b, f.name), f.name
    assert a.dispatches <= b.dispatches      # fusion never adds dispatches


def test_fleet_lockstep_parity_and_dispatch_reduction():
    """The fleet acceptance property: lockstep multi-guest execution
    reproduces every report metric bit for bit vs both the sequential plan
    path and the pre-plan legacy path, while issuing >= 2x fewer physical
    probe dispatches per tick than the legacy per-guest loop."""
    from repro.core.fleet import FleetSim, _run_lockstep
    combos = (("eevdf", "on"), ("cas", "on"), ("cas", "off"))
    kw = dict(n_intervals=6, warmup=2, seed=0)

    legacy_sims = [FleetSim(FAST_PLATFORM, policy=p, cap=c,
                            use_plans=False, **kw) for p, c in combos]
    d0 = probe_dispatch_count()
    legacy = [s.run() for s in legacy_sims]
    legacy_loop = probe_dispatch_count() - d0

    seq = [FleetSim(FAST_PLATFORM, policy=p, cap=c, **kw).run()
           for p, c in combos]

    lock_sims = [FleetSim(FAST_PLATFORM, policy=p, cap=c, **kw)
                 for p, c in combos]
    d0 = probe_dispatch_count()
    lock = _run_lockstep(lock_sims)
    lock_loop = probe_dispatch_count() - d0

    skip = ("dispatches", "wall_s", "guests_per_sec")
    for l, s, k in zip(legacy, seq, lock):
        for f in dataclasses.fields(type(l)):
            if f.name in skip:
                continue
            assert getattr(l, f.name) == getattr(s, f.name), f.name
            assert getattr(s, f.name) == getattr(k, f.name), f.name
    # the acceptance ratio: physical probe dispatches per tick, whole fleet
    assert legacy_loop >= 2 * lock_loop, (legacy_loop, lock_loop)
