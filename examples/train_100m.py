"""End-to-end training driver: train a qwen-family model with the full
stack (sharded step, async checkpoints, CacheX-TPU monitor, straggler
mitigation) and restart-proof data.

Default is a CPU-friendly ~2M-parameter model for 60 steps (~2 min).  The
same driver scales to the ~100M configuration with flags — on a real pod
this is `--preset 100m --steps 300`:

    PYTHONPATH=src python examples/train_100m.py                 # smoke
    PYTHONPATH=src python examples/train_100m.py --preset 100m \
        --steps 300 --ckpt /tmp/ckpt100m                         # full

Kill it at any point and re-run: it resumes from the latest checkpoint.
"""

import argparse
import dataclasses

from repro.configs.base import ArchConfig, ShapeSpec, get_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.tpuprobe.monitor import PodMonitor, SimClock
from repro.train import train_step as ts
from repro.train.trainer import Trainer, TrainerConfig

PRESETS = {
    "2m": dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_ff=512,
               vocab=2048, seq=128, batch=8, microbatches=2),
    "20m": dict(n_layers=8, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
                vocab=8192, seq=256, batch=16, microbatches=4),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
                 d_ff=3072, vocab=32000, seq=512, batch=32, microbatches=8),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="2m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--simulate-straggler", action="store_true")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    base = get_config("qwen1p5_0p5b")
    cfg = dataclasses.replace(
        base, name=f"qwen-{args.preset}", n_layers=p["n_layers"],
        d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], d_ff=p["d_ff"], vocab=p["vocab"])
    shape = ShapeSpec("train", p["seq"], p["batch"], "train")
    mesh = make_host_mesh()
    hyper = ts.TrainHyper(microbatches=p["microbatches"], remat="none")

    monitor = None
    if args.simulate_straggler:
        monitor = PodMonitor(
            n_devices=4,
            clock=SimClock(lambda d, t: 3.0 if d == 1 and t > 5 else 1.0))

    trainer = Trainer(cfg, shape, mesh, hyper,
                      TrainerConfig(ckpt_dir=args.ckpt, ckpt_every=20,
                                    data=DataConfig(seed=1234)),
                      monitor=monitor)
    log = trainer.run(args.steps)
    for r in log:
        if r["step"] % 10 == 0 or r["step"] <= 3:
            extra = f" mb_plan={r['mb_plan']}" if "mb_plan" in r else ""
            print(f"step {r['step']:4d} loss {r['loss']:.4f} "
                  f"gnorm {r['grad_norm']:.2f} lr {r['lr']:.2e} "
                  f"{r['wall_s']:.2f}s{extra}")
    print(f"\nfinal loss {log[-1]['loss']:.4f} "
          f"(from {log[0]['loss']:.4f}); checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
