"""Adversarial co-tenancy demo: attack, detect, defend, recover.

Walks the full adversarial story on one platform:

  1. a victim attaches a `CacheXSession` and monitors as usual; a
     malicious co-tenant (`AttackerGuest`) boots a second VM on the same
     host, pays its own attach, and *profiles* the victim's hot cells
     with no hypercalls — the victim's own priming overwrites the
     attacker's lines, ranking the shared cells by activity;
  2. the attack runs: a deterministic whole-set priming stream over the
     chosen targets, observed by the attacker through windowed
     Prime+Probe plans (``attack.primeprobe``);
  3. detection: the victim's `CacheShield` (enabled by
     `subscribe_attack`) classifies the concentrated persistent bursts
     as an attack, quarantines exactly the attacked sets out of the
     CAS/CAP aggregates — and raises zero `DriftSignal`s: an attack is
     interference, not a broken abstraction, so nothing gets repaired;
  4. defense, closed-loop: `FleetSim(attack=True)` sustains detection
     for `AttackSpec.defend_after` intervals, then schedules a ``cat``
     `HostEvent` isolating the victim's ways.  The re-carve flows
     through the *normal* drift path (DriftSignal -> repair -> CAP
     rebucket) and the sensitive task's quiet-domain residency recovers.

    PYTHONPATH=src python examples/attack_defense.py [platform]
"""

import sys

import numpy as np

from repro.core import (AttackerGuest, CacheXSession, ProbeConfig,
                        get_platform)
from repro.core.fleet import FleetSim


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "skylake_sp"
    plat = get_platform(name)
    print(f"== Adversarial co-tenancy on {name} ({plat.description}) ==\n")

    # -- victim + attacker share one host ------------------------------------
    host, vm = plat.make_host_vm(seed=7)
    session = CacheXSession.attach(
        vm, plat, ProbeConfig.for_platform(plat, seed=7,
                                           prune_self_conflicts=True))
    n_mon = len(session.monitored_sets())
    drifts, attacks = [], []
    session.subscribe_drift(drifts.append)
    session.subscribe_attack(attacks.append)

    atk = AttackerGuest(host, plat, seed=7)
    print(f"victim monitors {n_mon} sets; attacker attached for "
          f"{atk.attach_dispatches} dispatches")

    # -- profile: find the victim without hypercalls -------------------------
    act = atk.profile(rounds=2, between=lambda: session.refresh())
    k = max(1, int(0.34 * n_mon))
    targets = atk.choose_targets(k=k)
    print(f"profiled {len(act)} own cells (mean activity "
          f"{float(np.mean(act)):.2f}); attacking {len(targets)} targets: "
          f"{targets}")

    # -- attack + detect -----------------------------------------------------
    atk.begin()
    for w in range(8):
        session.refresh()
        if attacks:
            break
    sig = attacks[0]
    print(f"\ndetected after {w + 1} windows: kind={sig.kind} "
          f"sets={sig.set_indices} score={sig.score:.1f}")
    vs = session._vs
    print(f"quarantined (attack-flagged): "
          f"{sorted(int(i) for i in np.flatnonzero(vs.attack_flagged))}")
    print(f"false DriftSignals: {len(drifts)} (attack != drift); "
          f"repair has nothing to do: "
          f"anything_broken={session.repair().anything_broken}")

    # -- attacker stops: quarantine lifts ------------------------------------
    atk.stop()
    for _ in range(6):
        session.refresh()
    print(f"attacker stopped: under_attack={session.shield.under_attack}, "
          f"still flagged={int(vs.flagged.sum())} "
          f"(confirm_clean lifted the quarantine)\n")

    # -- the closed defense loop ---------------------------------------------
    sim = FleetSim(name, attack=True, with_poisoner=False, n_intervals=18)
    rep = sim.run()
    print(f"fleet defense: detected={rep.attack_detected} after "
          f"{rep.attack_detect_intervals} intervals, defenses={rep.defenses} "
          f"(CAT -> {sim.plat.attack.isolate_ways} ways), "
          f"false_drift={rep.false_drift}, repairs={rep.repairs}")
    print(f"quiet-domain residency pre/during/post: "
          f"{rep.residency_pre:.2f}/{rep.residency_during:.2f}/"
          f"{rep.residency_post:.2f}")
    ok = (rep.attack_detected and rep.false_drift == 0
          and rep.residency_post >= rep.residency_pre)
    print(f"\nclosed loop {'holds' if ok else 'FAILED'}: attack detected, "
          f"zero false drift, residency recovered")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
