"""Elastic restart demo: checkpoint on one mesh, resume on another.

Simulates a pod failure: training starts on a 4x2 mesh, "loses" half its
data-parallel ways, and resumes bit-exactly on a 2x4 mesh with the global
batch preserved via gradient-accumulation replanning.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import ShapeSpec, get_config, reduced_config
from repro.data.pipeline import DataConfig, make_batch
from repro.distributed.elastic import replan_batch, restore_on_mesh
from repro.train import train_step as ts

CKPT = "/tmp/repro_elastic_ckpt"


def steps_on(mesh, cfg, shape, hyper, state, start, n, seed=3):
    jitted, astate, st_shard, bshard = ts.jit_train_step(cfg, mesh, hyper,
                                                         shape)
    import jax.numpy as jnp
    with mesh:
        if state is None:
            state = jax.jit(lambda k: ts.make_train_state(cfg, hyper, k),
                            out_shardings=st_shard)(jax.random.PRNGKey(0))
        losses = []
        for step in range(start, start + n):
            hb = make_batch(DataConfig(seed=seed), cfg, shape, step)
            batch = {k: jax.device_put(jnp.asarray(v), bshard[k])
                     for k, v in hb.items() if k in bshard}
            state, m = jitted(state, batch)
            losses.append(float(m["loss"]))
    return state, losses


def main():
    cfg = reduced_config(get_config("qwen1p5_0p5b"))
    shape = ShapeSpec("elastic", 64, 16, "train")
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    mesh_b = jax.make_mesh((2, 4), ("data", "model"))
    hyper_a = ts.TrainHyper(microbatches=2, remat="none")

    print("phase 1: 4x2 mesh (data=4, model=2), 6 steps")
    state, l1 = steps_on(mesh_a, cfg, shape, hyper_a, None, 0, 6)
    ckpt.save(CKPT, 6, state)
    print(f"  losses: {[f'{x:.3f}' for x in l1]}  -> checkpoint @ step 6")

    new_mb = replan_batch(shape.global_batch, old_dp=4, new_dp=2,
                          old_microbatches=2)
    hyper_b = ts.TrainHyper(microbatches=new_mb, remat="none")
    print(f"phase 2: 'pod failure' -> 2x4 mesh; grad-accum replanned "
          f"2 -> {new_mb} (global batch preserved)")
    restored = restore_on_mesh(CKPT, 6, cfg, hyper_b, mesh_b)
    _, l2 = steps_on(mesh_b, cfg, shape, hyper_b, restored, 6, 6)
    print(f"  losses: {[f'{x:.3f}' for x in l2]}")
    assert l2[0] < l1[0], "resumed run must continue improving"
    print("elastic restart OK: training continued across the mesh change")


if __name__ == "__main__":
    main()
