"""Quickstart: probe a (simulated) cloud VM's caches with CacheX.

Runs the full probing pipeline of the paper against the simulated host:
VEV builds color filters and LLC eviction sets, VCOL assigns virtual
colors, VSCAN monitors contention from a co-located polluter, and the CAS
tier tracker reacts — all in under a minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.cachesim import CacheGeometry, MachineGeometry
from repro.core.cas import TierTracker
from repro.core.color import VCOL, color_accuracy
from repro.core.eviction import VEV
from repro.core.host_model import (CotenantWorkload, GuestVM, SimHost,
                                   polluter_gen)
from repro.core.vscan import VScan, theoretical_coverage


def main():
    geom = MachineGeometry(n_domains=1, cores_per_domain=2,
                           l2=CacheGeometry(n_sets=256, n_ways=8),
                           llc=CacheGeometry(n_sets=512, n_ways=8,
                                             n_slices=2))
    host = SimHost(geom, n_host_pages=1 << 14, seed=0)
    vm = GuestVM(host, n_guest_pages=1 << 13, mapping="fragmented",
                 vcpu_cores=[0])

    print("== VEV: LLC associativity ==")
    vev = VEV(vm)
    pool = vev.make_pool(0, ways=8, n_uncontrollable_rows=8, n_slices=2)
    ways = vev.probe_associativity(pool, "llc")
    print(f"detected LLC associativity: {ways} (hardware: "
          f"{geom.llc.n_ways})")

    print("\n== VCOL: virtual page colors ==")
    vcol = VCOL(vm)
    cf = vcol.build_color_filters(n_colors=4, ways=8)
    pages = vm.alloc_pages(64)
    colors = vcol.identify_colors_parallel(cf, pages)
    acc = color_accuracy(vm, pages, colors, 4)
    hist = np.bincount(colors, minlength=4)
    print(f"filters: {cf.n_colors}, color histogram: {hist.tolist()}, "
          f"accuracy vs hypercall: {acc:.0%}")

    print("\n== VSCAN: contention monitoring ==")
    pool_pages = vm.alloc_pages(8 * 8 * 2 * 3)
    vs, info = VScan.build(vm, cf, vcol, pool_pages, ways=8, f=2,
                           offsets=[0], domain_vcpus={0: [0]})
    print(f"monitored sets: {len(vs.monitored)} "
          f"(theoretical row coverage f=2, n=2: "
          f"{theoretical_coverage(2, 2):.1f}%)")
    idle = vs.monitor_once()
    print(f"idle host: eviction fraction {idle.eviction_frac.mean():.3f}")

    wl = CotenantWorkload("polluter", 0, rate_per_ms=200.0,
                          gen=polluter_gen(region_pages=2048))
    host.add_cotenant(wl)
    tiers = TierTracker(keys=[0], thresholds=[0.5, 4.0])
    for i in range(4):
        snap = vs.monitor_once()
        tiers.update(vs.per_domain_rate())
        print(f"interval {i}: evict frac {snap.eviction_frac.mean():.3f} "
              f"rate {vs.per_domain_rate()[0]:.2f}%/ms "
              f"tier {tiers.tier[0]} window {snap.window_ms:.0f}ms")
    print("\nCAS would now steer tasks away from domain 0 "
          f"(committed tier {tiers.tier[0]}).")


if __name__ == "__main__":
    main()
