"""ProbePlan demo: every measurement is a declarative program.

Shows the IR three ways:

  1. inspect — `session.plan()` returns one VSCAN monitoring interval as
     data (op signature, dispatch cost) before anything runs;
  2. execute / re-run — the same plan object runs repeatedly through the
     one executor, each run measuring fresh machine state;
  3. vectorize over guests — three co-running guests' monitoring plans
     co-execute as ONE program (`probeplan.execute_many`): one dispatch
     per probe point for the whole fleet, bit-identical per-guest rates.

    PYTHONPATH=src python examples/probe_plans.py [platform]
"""

import sys

import numpy as np

from repro.core import (CacheXSession, CotenantWorkload, ProbeConfig,
                        get_platform, probe_dispatch_count)
from repro.core import probeplan
from repro.core.host_model import polluter_gen


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "skylake_sp"
    plat = get_platform(name)
    print(f"== ProbePlans on {name} ({plat.description}) ==\n")

    # -- 1. inspect: the monitoring interval as data ------------------------
    host, vm = plat.make_host_vm(seed=5)
    session = CacheXSession.attach(vm, plat,
                                   ProbeConfig.for_platform(plat, seed=5))
    plan = session.plan()
    print(f"monitor plan: ops {plan.signature()}, "
          f"{plan.n_dispatches} dispatches, "
          f"{len(plan.ops[-1].lanes)} probe lanes, "
          f"hints {plan.hints}")

    # -- 2. execute + re-run: same program, fresh state every run -----------
    quiet = session.execute(plan).mean_rate
    host.add_cotenant(CotenantWorkload("burst", 0, 200.0,
                                       polluter_gen(region_pages=2048)))
    noisy = session.execute(session.plan()).mean_rate
    print(f"re-running the interval: quiet {quiet:.2f} -> "
          f"contended {noisy:.2f} %-lines/ms")

    # -- 3. vectorize over guests ------------------------------------------
    guests = []
    for seed in (11, 12, 13):
        h, v = plat.make_host_vm(seed=seed)
        s = CacheXSession.attach(v, plat,
                                 ProbeConfig.for_platform(plat, seed=seed))
        s.monitored_sets()
        guests.append((v, s))
    plans = [s.plan() for _, s in guests]
    before = probe_dispatch_count()
    results = probeplan.execute_many([v for v, _ in guests], plans)
    joint = probe_dispatch_count() - before
    views = [s.apply(p, r)
             for (_, s), p, r in zip(guests, plans, results)]
    print(f"\n3 guests' intervals co-executed: {joint} physical dispatches "
          f"(vs {sum(p.n_dispatches for p in plans)} run one by one)")
    for i, view in enumerate(views):
        print(f"  guest {i}: mean rate {view.mean_rate:.2f} %-lines/ms, "
              f"window {view.window_ms:.0f} ms")
    assert joint < sum(p.n_dispatches for p in plans)


if __name__ == "__main__":
    main()
