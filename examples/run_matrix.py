"""Scenario-matrix demo: the same CacheX stack vs every provisioning.

Runs the full VEV -> VCOL -> VSCAN -> CAS/CAP pipeline (`run_cachex`, a
thin report-builder over `CacheXSession`) against each registered
`CachePlatform` — dedicated, CAT-way-partitioned, slice-partitioned and
co-tenant-shared LLCs on Skylake-, Ice-Lake- and Milan-like geometries —
and prints one report row per scenario.  This is the paper's thesis in one
table: the guest never learns which scenario it landed on, yet probes the
right abstraction everywhere (the CAT row *discovers* its 4-way
allocation; the shared row succeeds through noise by majority voting).

    PYTHONPATH=src python examples/run_matrix.py           # pretty table
    PYTHONPATH=src python examples/run_matrix.py --csv     # headered CSV
                                                           # (columns ==
                                                           # CacheXReport
                                                           # fields)
"""

import sys

from repro.core import CacheXReport, get_platform, list_platforms, run_cachex

HDR = (f"{'platform':18s} {'provisioning':12s} {'vev':>5s} {'ways':>4s} "
       f"{'vcol':>5s} {'idle':>6s} {'hot':>6s} {'disp':>6s} {'wall':>7s}")


def main():
    as_csv = "--csv" in sys.argv[1:]
    if as_csv:
        print(CacheXReport.csv_header())
    else:
        print("== CacheX across the provisioned-cache scenario matrix ==\n")
        print(HDR)
        print("-" * len(HDR))
    for name in list_platforms():
        plat = get_platform(name)
        r = run_cachex(name, seed=17, monitor_intervals=2)
        if as_csv:
            print(r.csv_row())
            continue
        ways = (f"{r.detected_ways}/{plat.llc_ways_total}"
                if plat.provisioning == "cat" else f"{r.detected_ways}")
        print(f"{r.platform:18s} {r.provisioning:12s} "
              f"{100 * r.vev_success_rate:4.0f}% {ways:>4s} "
              f"{100 * r.vcol_accuracy:4.0f}% "
              f"{r.vscan_idle_rate:6.2f} {r.vscan_contended_rate:6.2f} "
              f"{r.dispatches:6d} {r.wall_s:6.1f}s")
    if not as_csv:
        print("\nvev/vcol: hypercall-verified success rates; ways: detected "
              "(CAT shows allocation/hardware);")
        print("idle/hot: VSCAN eviction rate (%-lines/ms) quiesced vs under "
              "a polluter; disp: jitted probe dispatches.")


if __name__ == "__main__":
    main()
