"""Scenario-matrix demo: the same CacheX stack vs every provisioning.

Runs the full VEV -> VCOL -> VSCAN -> CAS/CAP pipeline (`run_cachex`)
against each registered `CachePlatform` — dedicated, CAT-way-partitioned,
slice-partitioned and co-tenant-shared LLCs on Skylake-, Ice-Lake- and
Milan-like geometries — and prints one report row per scenario.  This is
the paper's thesis in one table: the guest never learns which scenario it
landed on, yet probes the right abstraction everywhere (the CAT row
*discovers* its 4-way allocation; the shared row succeeds through noise by
majority voting).

    PYTHONPATH=src python examples/run_matrix.py
"""

from repro.core.platforms import get_platform, list_platforms
from repro.core.runner import run_cachex

HDR = (f"{'platform':18s} {'provisioning':12s} {'vev':>5s} {'ways':>4s} "
       f"{'vcol':>5s} {'idle':>6s} {'hot':>6s} {'disp':>6s} {'wall':>7s}")


def main():
    print("== CacheX across the provisioned-cache scenario matrix ==\n")
    print(HDR)
    print("-" * len(HDR))
    for name in list_platforms():
        plat = get_platform(name)
        r = run_cachex(name, seed=17, monitor_intervals=2)
        ways = (f"{r.detected_ways}/{plat.llc_ways_total}"
                if plat.provisioning == "cat" else f"{r.detected_ways}")
        print(f"{r.platform:18s} {r.provisioning:12s} "
              f"{100 * r.vev_success_rate:4.0f}% {ways:>4s} "
              f"{100 * r.vcol_accuracy:4.0f}% "
              f"{r.vscan_idle_rate:6.2f} {r.vscan_contended_rate:6.2f} "
              f"{r.dispatches:6d} {r.wall_s:6.1f}s")
    print("\nvev/vcol: hypercall-verified success rates; ways: detected "
          "(CAT shows allocation/hardware);")
    print("idle/hot: VSCAN eviction rate (%-lines/ms) quiesced vs under a "
          "polluter; disp: jitted probe dispatches.")


if __name__ == "__main__":
    main()
