"""CacheXSession demo: attach -> query -> export -> reboot -> import.

The paper's product is the *abstraction* the guest ends up holding; this
demo drives it purely through the first-class query API:

  1. attach a `CacheXSession` to a freshly booted platform (the VEV ->
     VCOL -> VSCAN pipeline runs lazily behind the queries),
  2. query `topology()`, `colors()` and `contention()` (with a subscribed
     consumer receiving every published update),
  3. `export()` the probed abstraction to JSON,
  4. *reboot* the guest (the hypervisor keeps the memory backing) and
     `import_()` the JSON into a session on the fresh VM — zero re-probing,
  5. validate the imported answers against hypercall ground truth (§6.2)
     and re-measure contention with the imported monitored sets.

    PYTHONPATH=src python examples/abstraction_api.py [platform] [out.json]
"""

import sys

from repro.core import CacheXSession, ProbeConfig, get_platform


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "skylake_sp"
    out = sys.argv[2] if len(sys.argv) > 2 else "abstraction.json"
    plat = get_platform(name)
    print(f"== CacheXSession on {name} ({plat.description}) ==\n")

    host, vm = plat.make_host_vm(seed=11)
    session = CacheXSession.attach(vm, plat,
                                   ProbeConfig.for_platform(plat, seed=11))

    topo = session.topology()
    print(f"topology: {topo.n_domains} LLC domain(s), "
          f"effective ways {topo.effective_ways}, "
          f"detected associativity {topo.detected_associativity} "
          f"(hardware {plat.llc_ways_total}), "
          f"{topo.vev_built_sets}/{topo.vev_target_sets} eviction sets")

    colors = session.colors()
    pages = vm.alloc_pages(8 * colors.n_colors)
    per_color = {c: int((colors.colors_of(pages) == c).sum())
                 for c in range(colors.n_colors)}
    print(f"colors:   {colors.n_colors} virtual colors; "
          f"{len(pages)} pages colored -> {per_color}")

    updates = []
    session.subscribe(lambda view: updates.append(view.interval))
    view = session.contention()
    print(f"contention: mean rate {view.mean_rate:.2f} %-lines/ms "
          f"(window {view.window_ms:.0f} ms, interval #{view.interval}, "
          f"age {view.age_ms(vm.host.time_ms):.1f} ms); "
          f"subscriber saw updates {updates}")

    session.export_json(out)
    print(f"\nexported abstraction -> {out}")

    vm2 = vm.reboot(seed=12)
    probes_before = vm2.stat_passes
    restored = CacheXSession.import_json(vm2, open(out).read())
    t2 = restored.topology()
    parity = (t2 == topo and
              (restored.colors().colors_of(pages)
               == colors.colors_of(pages)).all())
    check = restored.validate()
    reprobes = vm2.stat_passes - probes_before
    print(f"rebooted + imported: re-probe dispatches {reprobes}, "
          f"topology/colors parity {parity}")
    print(f"hypercall validation: vcol accuracy "
          f"{100 * check['vcol_accuracy']:.0f}%, VEV verified "
          f"{check['vev_verified']}/{check['vev_built']}, "
          f"ways match {check['ways_match']}")
    v2 = restored.refresh()
    print(f"re-measured contention on imported monitored sets: "
          f"mean rate {v2.mean_rate:.2f} %-lines/ms")
    assert parity and reprobes == 0, \
        "import must reproduce answers without re-probing"
    assert check["ways_match"], "detected associativity must match"
    if plat.l2_filter_reliable and not plat.noise:
        # quiet, reliable scenarios carry the paper's 100% guarantees
        assert check["vcol_accuracy"] == 1.0, "vcol ground truth regressed"
        assert check["vev_verified"] == check["vev_built"], \
            "VEV ground truth regressed"


if __name__ == "__main__":
    main()
