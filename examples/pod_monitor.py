"""Pod backend demo: the same session API over a TPU-pod probing target.

The paper probes a hypervisor-hidden LLC; a pod tenant faces the same
asymmetry for effective VMEM, per-chip HBM bandwidth and per-axis ICI
health.  This demo drives `CacheXSession.attach(backend="pod")` end to
end against the deterministic `SimPod` host model:

  1. attach with the pod backend and query `topology()` (mesh axes +
     per-chip probed effective VMEM), `colors()` (VMEM/HBM arena zones)
     and `contention()` (per-chip slowdowns, per-axis ICI health),
  2. subscribe the LM-stack consumers — `ReplicaRouter` tiers,
     `StragglerMitigator` microbatch plans, `ExpertRebalancer` — and
     watch them act on published windows as one chip heats up,
  3. export the probed abstraction, reprovision the pod (epoch bump) and
     show the import is rejected as stale, then repaired,
  4. run the closed loop (`run_pod_loop`) rebalance on vs off and print
     the measured p99 decode latency / step time delta.

    PYTHONPATH=src python examples/pod_monitor.py
"""

import numpy as np

from repro.core import CacheXSession, StaleAbstractionError, TierTracker
from repro.distributed.rebalance import ExpertRebalancer, StragglerMitigator
from repro.tpuprobe.pod_backend import SimPod, run_pod_loop


def main():
    print("== CacheXSession on the pod backend ==\n")
    pod = SimPod(mesh_shape={"data": 2, "model": 4}, seed=11,
                 reserved_vmem=(3 << 20) + 12345,
                 hbm_schedule=lambda chip, t: 2.5 if (chip == 5 and t > 30)
                 else 1.0,
                 link_schedule=lambda ax, hop, t: 1.8
                 if (ax == "model" and hop == 1) else 1.0)
    session = CacheXSession.attach(pod.slice(), "pod", backend="pod")

    topo = session.topology()
    vmem_mib = topo.effective_vmem[0] / (1 << 20)
    print(f"topology: mesh {topo.axes} -> {topo.n_chips} chips; "
          f"probed effective VMEM {vmem_mib:.2f} MiB/chip "
          f"(nominal 16.00); axis slowdowns "
          f"{ {a: round(s, 2) for a, s in topo.axis_slowdown.items()} }")
    colors = session.colors()
    print(f"colors:   {colors.n_zones} arena zones "
          f"(chip 0: hbm={colors.zone_of(0, 'hbm')}, "
          f"vmem={colors.zone_of(0, 'vmem')})")

    tiers = TierTracker(keys=list(range(topo.n_chips)),
                        thresholds=[1.15, 1.5])
    mitigator = StragglerMitigator(topo.n_chips, total_microbatches=32)
    experts = ExpertRebalancer(16, topo.n_chips, experts_per_device=2)
    session.subscribe(tiers.on_contention)
    session.subscribe(mitigator.on_contention)
    session.subscribe(experts.on_contention)
    experts.update_load(np.linspace(16, 1, 16))

    print("\nwindow  chip5_ewma  tier5  microbatch_plan")
    for _ in range(12):
        view = session.refresh()
        print(f"  #{view.interval:<4} {view.per_domain[5]:>9.2f} "
              f"{tiers.tier[5]:>6}  {[int(x) for x in mitigator.plan]}")
    print(f"expert re-placements after tier commit: "
          f"{experts.rebalances} (moved {experts.moves} bindings)")

    js = session.export_json()
    pod.reprovision(reserved_vmem=5 << 20)
    try:
        CacheXSession.import_(pod.slice(), __import__("json").loads(js))
        raise AssertionError("stale import must be rejected")
    except StaleAbstractionError as e:
        print(f"\nreprovisioned pod rejects the old export: "
              f"{str(e).splitlines()[0][:60]}...")
    from repro.tpuprobe.pod_backend import PodSession
    stale = PodSession.import_json(pod.slice(), js, allow_stale=True)
    rep = stale.repair()
    new_mib = stale.topology().effective_vmem[0] / (1 << 20)
    print(f"repair(): re-probed VMEM {vmem_mib:.2f} -> {new_mib:.2f} "
          f"MiB/chip (epoch {rep['epoch']})")

    print("\nclosed pod loop (probe -> tier -> reroute/rebalance -> "
          "measure):")
    on = run_pod_loop(rebalance="on", seed=0)
    off = run_pod_loop(rebalance="off", seed=0)
    print(f"  rebalance off: p99 decode {off.p99_decode_ms:.2f} ms, "
          f"step {off.mean_step_s * 1e3:.2f} ms, "
          f"hot-chip requests {100 * off.hot_request_frac:.0f}%")
    print(f"  rebalance on:  p99 decode {on.p99_decode_ms:.2f} ms, "
          f"step {on.mean_step_s * 1e3:.2f} ms, "
          f"hot-chip requests {100 * on.hot_request_frac:.0f}% "
          f"({on.rebalances} microbatch rebalances, "
          f"{on.expert_moves} expert moves)")
    assert on.p99_decode_ms < off.p99_decode_ms
    assert on.mean_step_s < off.mean_step_s
    print("  -> closed loop improves both (measured, not assumed)")


if __name__ == "__main__":
    main()
