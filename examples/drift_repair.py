"""Drift epochs demo: the host changes under you; the abstraction heals.

Walks the full drift story on one platform:

  1. attach a `CacheXSession`, probe everything, export the abstraction;
  2. the host silently misbehaves — a partial page remap and a CAT
     repartition land as `HostEvent`s *mid-probe* (while the guest
     waits), bumping the hidden host epoch;
  3. detection, two ways: `validate()` (hypercall ground truth + epoch
     staleness, §6.2-style) and the guest's own `check_drift()` /
     `DriftSignal` subscription (sustained probe anomalies, zero-wait
     confirmed);
  4. `session.repair()` fixes only what broke — surviving members +
     spares rebuild the broken sets in two fused rounds, only
     invalidated pages recolor — at a fraction of a re-probe's
     dispatches;
  5. the pre-drift export now refuses to import (`StaleAbstractionError`)
     unless `allow_stale=True` + `repair()`.

    PYTHONPATH=src python examples/drift_repair.py [platform]
"""

import sys

from repro.core import (CacheXSession, HostEvent, ProbeConfig,
                        StaleAbstractionError, get_platform)


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "skylake_sp"
    plat = get_platform(name)
    print(f"== Drift epochs on {name} ({plat.description}) ==\n")

    host, vm = plat.make_host_vm(seed=42)
    session = CacheXSession.attach(
        vm, plat, ProbeConfig.for_platform(plat, seed=42), eager=True)
    pages = vm.alloc_pages(8 * plat.n_l2_colors)
    session.colors().colors_of(pages)
    session.refresh()
    attach_dispatches = vm.stat_passes
    snapshot = session.export_json()
    print(f"probed abstraction: {attach_dispatches} dispatches, "
          f"epoch {session.topology().epoch}, host epoch {host.epoch}")

    signals = []
    session.subscribe_drift(signals.append)

    # -- the host drifts: events land while the guest waits ------------------
    host.schedule_event(HostEvent(at_ms=host.time_ms + 0.5, kind="remap",
                                  fraction=0.25,
                                  note="compaction rebacks 25%"))
    vm.wait_ms(1.0)
    truth = session.validate()
    print(f"\nafter silent 25% remap: stale={truth['stale']} "
          f"(host epoch {truth['host_epoch']}), vcol accuracy "
          f"{truth['vcol_accuracy']:.0%}, VEV verified "
          f"{truth['vev_verified']}/{truth['vev_built']}")
    check = session.check_drift()
    broken = {k: int((~v).sum()) for k, v in check.items()
              if k != "any_broken"}
    print(f"guest-side check_drift(): broken per stage = {broken}")

    d0 = vm.stat_passes
    report = session.repair()
    print(f"repair(): {vm.stat_passes - d0} dispatches "
          f"(vs {attach_dispatches} to re-probe, "
          f"{attach_dispatches / max(1, vm.stat_passes - d0):.0f}x less) — "
          f"{report.llc_repaired + report.vscan_repaired} sets repaired "
          f"from survivors, {report.pages_recolored} pages recolored, "
          f"epoch -> {report.epoch}")
    truth = session.validate()
    assert not truth["stale"] and truth["vev_verified"] == truth["vev_built"]

    # -- a CAT repartition: detected by the monitor itself -------------------
    host.schedule_event(HostEvent(at_ms=host.time_ms + 0.5, kind="cat",
                                  new_llc_ways=max(
                                      2, plat.effective_ways // 2),
                                  note="hypervisor halves the allocation"))
    vm.wait_ms(1.0)
    for k in range(6):
        session.refresh()
        if signals:
            break
    sig = signals[-1]
    print(f"\nCAT repartition: DriftSignal({sig.kind}) after {k + 1} "
          f"intervals, {len(sig.set_indices)} monitored sets quarantined")
    report = session.repair()
    topo = session.topology()
    print(f"repair(): re-detected associativity "
          f"{topo.detected_associativity} (was {plat.effective_ways}), "
          f"every set re-minimalized, epoch -> {topo.epoch}")

    # -- the pre-drift export is now poison ----------------------------------
    vm2 = vm.reboot(seed=43)
    try:
        CacheXSession.import_json(vm2, snapshot)
        raise AssertionError("stale import must fail")
    except StaleAbstractionError as e:
        print(f"\nimporting the pre-drift export: StaleAbstractionError "
              f"(as it should be)")
    salvaged = CacheXSession.import_json(vm2, snapshot, allow_stale=True)
    rep = salvaged.repair()
    truth = salvaged.validate()
    print(f"allow_stale + repair(): {rep.dispatches} dispatches, "
          f"ways_match={truth['ways_match']}, stale={truth['stale']}")
    assert not truth["stale"]
    print("\ndrift OK: detected, signalled, incrementally repaired.")


if __name__ == "__main__":
    main()
