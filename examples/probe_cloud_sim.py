"""Paper §6.4 reproduction: dynamic/asymmetric LLC contention and page-color
skew in "cloud VMs" (Figs 8 & 9), against simulated providers.

Three simulated hosts play back the paper's observations through the
first-class `CacheXSession` API (no hand-wired probe stages):
  * aws-like:    persistent moderate contention,
  * azure-like:  quiescent with a late spike,
  * google-like: heavy + *asymmetric* across two LLC domains.

The Fig 9 half uses the drift timeline: hypervisor page remapping is a
scheduled `HostEvent` that lands while the guest waits, `validate()`
shows the silent staleness (epoch + accuracy), and `session.repair()`
recolors only the invalidated pages — the paper's "hourly rebuild"
strategy replaced by incremental repair.

    PYTHONPATH=src python examples/probe_cloud_sim.py
"""

import dataclasses

import numpy as np

from repro.core import CacheXSession, CachePlatform, ProbeConfig
from repro.core.cachesim import CacheGeometry
from repro.core.host_model import CotenantWorkload, HostEvent, polluter_gen

BASE = CachePlatform(
    name="cloud_base",
    description="Skylake-like scaled geometry for the provider sims",
    l2=CacheGeometry(n_sets=256, n_ways=8),
    llc=CacheGeometry(n_sets=512, n_ways=8, n_slices=2))

PROVIDERS = {
    "aws": dict(noise=[("steady", 0, 60.0, 1024)]),
    "azure": dict(noise=[]),                      # spike arrives mid-run
    "google": dict(n_domains=2,
                   noise=[("noisy", 0, 120.0, 2048),
                          ("mild", 1, 15.0, 512)]),
}


def boot(name, seed):
    spec = PROVIDERS[name]
    plat = dataclasses.replace(BASE, name=f"cloud_{name}",
                               n_domains=spec.get("n_domains", 1))
    host, vm = plat.make_host_vm(seed=seed)
    for wl_name, domain, rate, pages in spec["noise"]:
        host.add_cotenant(CotenantWorkload(
            wl_name, domain, rate,
            polluter_gen(region_pages=pages,
                         base_page=(1 << 18) + domain * (1 << 16))))
    session = CacheXSession.attach(vm, plat,
                                   ProbeConfig.for_platform(plat, seed=seed))
    return host, vm, session


def probe(name, intervals=12, seed=1):
    host, vm, session = boot(name, seed)
    session.monitored_sets()
    series = {d: [] for d in session.domain_vcpus()}
    for i in range(intervals):
        if name == "azure" and i == intervals - 3:
            host.add_cotenant(CotenantWorkload(
                "spike", 0, 200.0, polluter_gen(region_pages=2048)))
        view = session.refresh()
        for d in series:
            series[d].append(view.per_domain.get(d, 0.0))
    return series, (host, vm, session)


def spark(xs, scale):
    blocks = " ▁▂▃▄▅▆▇█"
    return "".join(blocks[min(8, int(x / scale * 8))] for x in xs)


def main():
    print("== Fig 8a: dynamic LLC contention (eviction rate %/ms) ==")
    results = {}
    for name in ("aws", "azure", "google"):
        series, ctx = probe(name)
        results[name] = (series, ctx)
        peak = max(max(v) for v in series.values()) or 1.0
        for d, xs in series.items():
            print(f"  {name:7s} LLC{d}: {spark(xs, peak)}  "
                  f"(mean {np.mean(xs):.2f}, last {xs[-1]:.2f})")

    g_series, _ = results["google"]
    asym = np.mean(g_series[0]) / max(np.mean(g_series[1]), 1e-3)
    print(f"\n  google domains asymmetry (LLC0/LLC1): {min(asym, 99.0):.1f}x "
          "(Fig 8b behaviour)")

    print("\n== Fig 9: page-color skew after hypervisor remapping ==")
    host, vm, session = results["aws"][1]
    pages = vm.alloc_pages(96)
    session.colors().colors_of(pages)
    acc0 = session.validate(pages=pages)["vcol_accuracy"]
    print(f"  t=0h   virtual-color accuracy: {acc0:.0%} "
          f"(host epoch {host.epoch})")
    for frac, label in ((0.1, "t=1h"), (0.5, "t=12h")):
        # the remap is a timeline event: it lands while the guest waits
        host.schedule_event(HostEvent(at_ms=host.time_ms + 0.5,
                                      kind="remap", fraction=frac))
        vm.wait_ms(1.0)
        truth = session.validate(pages=pages)
        print(f"  {label} (remap {frac:.0%}) stale-abstraction accuracy: "
              f"{truth['vcol_accuracy']:.0%}  (stale={truth['stale']}, "
              f"host epoch {truth['host_epoch']})")
    d0 = vm.stat_passes
    report = session.repair()
    acc1 = session.validate(pages=pages)["vcol_accuracy"]
    print(f"  after repair(): {acc1:.0%} — {report.pages_recolored} pages "
          f"recolored, {report.filters_repaired + report.filters_rebuilt} "
          f"filters fixed, {vm.stat_passes - d0} probe dispatches "
          "(incremental repair, paper §6.4's hourly rebuild made cheap)")
    assert acc1 == 1.0


if __name__ == "__main__":
    main()
