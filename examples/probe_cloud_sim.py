"""Paper §6.4 reproduction: dynamic/asymmetric LLC contention and page-color
skew in "cloud VMs" (Figs 8 & 9), against simulated providers.

Three simulated hosts play back the paper's observations:
  * aws-like:    persistent moderate contention,
  * azure-like:  quiescent with a late spike,
  * google-like: heavy + *asymmetric* across two LLC domains, plus periodic
                 hypervisor page remapping that skews virtual colors.

    PYTHONPATH=src python examples/probe_cloud_sim.py
"""

import numpy as np

from repro.core.cachesim import CacheGeometry, MachineGeometry
from repro.core.color import VCOL, color_accuracy
from repro.core.host_model import (CotenantWorkload, GuestVM, SimHost,
                                   polluter_gen, zipf_gen)
from repro.core.vscan import VScan

GEOM = dict(l2=CacheGeometry(n_sets=256, n_ways=8),
            llc=CacheGeometry(n_sets=512, n_ways=8, n_slices=2))


def make_provider(name, seed):
    if name == "google":
        geom = MachineGeometry(n_domains=2, cores_per_domain=2, **GEOM)
        host = SimHost(geom, n_host_pages=1 << 14, seed=seed)
        vm = GuestVM(host, n_guest_pages=1 << 13, mapping="fragmented",
                     vcpu_cores=[0, 1, 2, 3])
        host.add_cotenant(CotenantWorkload(
            "noisy", 0, 120.0, polluter_gen(region_pages=2048)))
        host.add_cotenant(CotenantWorkload(
            "mild", 1, 15.0, polluter_gen(region_pages=512,
                                          base_page=1 << 19)))
        return host, vm, {0: [0], 1: [2]}
    geom = MachineGeometry(n_domains=1, cores_per_domain=2, **GEOM)
    host = SimHost(geom, n_host_pages=1 << 14, seed=seed)
    vm = GuestVM(host, n_guest_pages=1 << 13, mapping="fragmented",
                 vcpu_cores=[0, 1])
    if name == "aws":
        host.add_cotenant(CotenantWorkload(
            "steady", 0, 60.0, polluter_gen(region_pages=1024)))
    return host, vm, {0: [0]}


def probe(name, intervals=12, seed=1):
    host, vm, domain_vcpus = make_provider(name, seed)
    vcol = VCOL(vm)
    cf = vcol.build_color_filters(n_colors=4, ways=8, seed=seed)
    pool = vm.alloc_pages(8 * 8 * 2 * 3)
    vs, _ = VScan.build(vm, cf, vcol, pool, ways=8, f=2, offsets=[0],
                        domain_vcpus=domain_vcpus, seed=seed)
    series = {d: [] for d in domain_vcpus}
    for i in range(intervals):
        if name == "azure" and i == intervals - 3:
            host.add_cotenant(CotenantWorkload(
                "spike", 0, 200.0, polluter_gen(region_pages=2048)))
        vs.monitor_once()
        for d, r in vs.per_domain_rate().items():
            series[d].append(r)
    return series, (vm, vcol, cf)


def spark(xs, scale):
    blocks = " ▁▂▃▄▅▆▇█"
    return "".join(blocks[min(8, int(x / scale * 8))] for x in xs)


def main():
    print("== Fig 8a: dynamic LLC contention (eviction rate %/ms) ==")
    results = {}
    for name in ("aws", "azure", "google"):
        series, ctx = probe(name)
        results[name] = (series, ctx)
        peak = max(max(v) for v in series.values()) or 1.0
        for d, xs in series.items():
            print(f"  {name:7s} LLC{d}: {spark(xs, peak)}  "
                  f"(mean {np.mean(xs):.2f}, last {xs[-1]:.2f})")

    g_series, _ = results["google"]
    asym = np.mean(g_series[0]) / max(np.mean(g_series[1]), 1e-3)
    print(f"\n  google domains asymmetry (LLC0/LLC1): {min(asym, 99.0):.1f}x "
          "(Fig 8b behaviour)")

    print("\n== Fig 9: page-color skew after hypervisor remapping ==")
    vm, vcol, cf = results["aws"][1]
    pages = vm.alloc_pages(96)
    colors = vcol.identify_colors_parallel(cf, pages)
    print(f"  t=0h   virtual-color accuracy: "
          f"{color_accuracy(vm, pages, colors, 4):.0%}")
    for frac, label in ((0.1, "t=1h"), (0.5, "t=12h")):
        vm._page_table = vm.host.remap_pages(vm._page_table, frac)
        acc = color_accuracy(vm, pages, colors, 4)
        print(f"  {label} (remap {frac:.0%}) stale-filter accuracy: "
              f"{acc:.0%}")
    vcol2 = VCOL(vm)
    cf2 = vcol2.build_color_filters(n_colors=4, ways=8, seed=99)
    colors2 = vcol2.identify_colors_parallel(cf2, pages)
    print(f"  after rebuild: {color_accuracy(vm, pages, colors2, 4):.0%} "
          "(hourly rebuild strategy, paper §6.4)")


if __name__ == "__main__":
    main()
