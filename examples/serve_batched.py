"""Batched serving demo: CAS-routed replicas + wave-batched greedy decode.

Two model "replicas" (as on two pods); the CacheX-TPU monitor reports one
replica contended, so the router steers new requests to the quiet one.

    PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.core.cas import TierTracker
from repro.models import lm
from repro.serve.engine import ReplicaRouter, Request, ServeEngine


def main():
    cfg = reduced_config(get_config("qwen1p5_0p5b"))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    engines = [ServeEngine(cfg, params, batch_slots=4, max_len=64)
               for _ in range(2)]

    # monitor says replica 0 is contended (3 consecutive intervals)
    tiers = TierTracker(keys=[0, 1], thresholds=[1.2])
    for _ in range(3):
        tiers.update({0: 5.0, 1: 0.3})
    router = ReplicaRouter(2, tiers=tiers)

    rng = np.random.default_rng(0)
    t0 = time.time()
    routed = {0: 0, 1: 0}
    for rid in range(8):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(3, 8))
        r = router.route()
        routed[r] += 1
        engines[r].submit(Request(rid=rid, prompt=prompt.astype(np.int32),
                                  max_new=8, replica=r))
    print(f"routing under contention on replica 0: {routed} "
          "(CAS prefers the quiet replica)")

    done = []
    for eng in engines:
        done.extend(eng.run_until_drained())
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {toks} tokens "
          f"in {dt:.1f}s ({toks/dt:.1f} tok/s on 1 CPU core)")
    for r in sorted(done, key=lambda r: r.rid)[:3]:
        print(f"  req {r.rid} (replica {r.replica}): "
              f"prompt {r.prompt.tolist()} -> {r.out}")


if __name__ == "__main__":
    main()
