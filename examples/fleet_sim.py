"""Closed-loop fleet demo: the probed abstraction changes decisions.

Co-runs the Fig 10-style fleet (a cache-sensitive task, a page-cache
streamer, a bursty batch task) on every registered platform under three
scheduling policies.  The CAS/CAP decisions ride `CacheXSession`
subscriptions: every `refresh()` publishes the *measured* per-domain /
per-color eviction rates to the subscribed TierTracker and CapAllocator —
the paper's probe→decide→act→measure loop (`repro.core.fleet`).  Prints
the Fig 10 domain-residency table and the Table 7/8-style speedup deltas.

    PYTHONPATH=src python examples/fleet_sim.py
    PYTHONPATH=src python examples/fleet_sim.py skylake_sp milan_ccx
"""

import sys

from repro.core.fleet import fig10_summary, run_fleet_matrix, speedup_summary
from repro.core.host_model import probe_dispatch_count


def main():
    platforms = sys.argv[1:] or None
    print("== Closed-loop CAS/CAP fleet across the platform matrix ==\n")
    d0 = probe_dispatch_count()
    reports = run_fleet_matrix(platforms=platforms)
    dispatches = probe_dispatch_count() - d0
    hdr = (f"{'platform':18s} {'policy':6s} {'cap':3s} {'thr':>7s} "
           f"{'quiet%':>6s} {'hot':>5s} {'quiet':>6s} {'ws_lat':>6s}")
    print(hdr)
    print("-" * len(hdr))
    for r in reports:
        print(f"{r.platform:18s} {r.policy:6s} {r.cap:3s} "
              f"{r.throughput:7.1f} {100 * r.quiet_residency:5.0f}% "
              f"{r.hot_rate:5.1f} {r.quiet_rate:6.2f} "
              f"{r.ws_lat_cycles:5.0f}c")

    f10 = fig10_summary(reports)
    print(f"\nFig 10: CAS steers the sensitive task to the quiet domain on "
          f"{f10['cas_quiet']}/{f10['n_platforms']} platforms; EEVDF stays "
          f"pinned on {f10['eevdf_pinned']}/{f10['n_platforms']} "
          f"(separated on {f10['separated']}).")
    print("\nTable 7/8 analog (throughput deltas):")
    for plat, row in speedup_summary(reports).items():
        print(f"  {plat:18s} CAS vs EEVDF {100 * row['cas_vs_eevdf']:+6.1f}%"
              f"   vs rusty {100 * row['cas_vs_rusty']:+6.1f}%"
              f"   CAP on-vs-off {100 * row['cap_on_vs_off']:+6.1f}%")
    print("\nthr: post-warmup IPC-model work; quiet%: sensitive-task "
          "residency in the unpolluted domain;")
    print("hot/quiet: measured VSCAN EWMA rates (%-lines/ms); ws_lat: "
          "measured working-set latency (cycles).")
    print(f"\n{dispatches} physical probe dispatches for the whole sweep: "
          "each platform's guests co-execute their per-tick ProbePlans in "
          "lockstep\n(one dispatch per probe point per tick; "
          "`benchmarks.run --only plans` quantifies the reduction).")


if __name__ == "__main__":
    main()
